//! The typed job layer: every compression request the coordinator can
//! serve, as data.
//!
//! [`JobSpec`] (what to do) and [`JobResult`] (what happened) are plain
//! enums with `util::json` codecs — the single wire vocabulary shared by
//! the CLI (`obc <cmd>`), the line-protocol server
//! (`examples/serve_compress.rs`, `obc serve`), and tests. This replaces
//! the stringly-typed dispatch that used to be duplicated between
//! `serve_compress.rs` and `main.rs`, and gives compound prune→quant
//! requests one entry point ([`JobSpec::JointNmQuant`]).
//!
//! Control operations ([`ControlOp`]: `shutdown`/`health`/`metrics`) are
//! a separate type from jobs — shutdown is a typed signal, not a
//! sentinel error string.

use super::engine::{CompressionEngine, LayerScope};
use super::methods::{PruneMethod, QuantMethod};
use crate::db::ModelDb;
use crate::util::error::Result;
use crate::util::json::{parse, Json};
use crate::util::precision::Precision;
use std::sync::Arc;

// ----------------------------------------------------------------------
// Method tokens (stable wire names)
// ----------------------------------------------------------------------

/// Wire token of a pruning method (lowercase, stable).
pub fn prune_method_token(m: PruneMethod) -> String {
    match m {
        PruneMethod::Gmp => "gmp".into(),
        PruneMethod::Lobs => "lobs".into(),
        PruneMethod::AdaPrune => "adaprune".into(),
        PruneMethod::AdaPruneIter(k) => format!("adaprune:{k}"),
        PruneMethod::ExactObs => "exactobs".into(),
    }
}

pub fn parse_prune_method(s: &str) -> Result<PruneMethod> {
    match s.to_lowercase().as_str() {
        "gmp" => Ok(PruneMethod::Gmp),
        "lobs" | "l-obs" => Ok(PruneMethod::Lobs),
        "adaprune" => Ok(PruneMethod::AdaPrune),
        "exactobs" | "obs" => Ok(PruneMethod::ExactObs),
        other => {
            if let Some(k) = other.strip_prefix("adaprune:") {
                let k: usize = k
                    .parse()
                    .map_err(|_| crate::err!("bad adaprune iteration count '{k}'"))?;
                return Ok(PruneMethod::AdaPruneIter(k));
            }
            Err(crate::err!(
                "unknown prune method '{other}' (gmp|lobs|adaprune|adaprune:<k>|exactobs)"
            ))
        }
    }
}

/// Wire token of a quantization method (lowercase, stable).
pub fn quant_method_token(m: QuantMethod) -> &'static str {
    match m {
        QuantMethod::Rtn => "rtn",
        QuantMethod::BitSplit => "bitsplit",
        QuantMethod::AdaQuant => "adaquant",
        QuantMethod::AdaRound => "adaround",
        QuantMethod::Obq => "obq",
    }
}

pub fn parse_quant_method(s: &str) -> Result<QuantMethod> {
    match s.to_lowercase().as_str() {
        "rtn" => Ok(QuantMethod::Rtn),
        "bitsplit" => Ok(QuantMethod::BitSplit),
        "adaquant" => Ok(QuantMethod::AdaQuant),
        "adaround" => Ok(QuantMethod::AdaRound),
        "obq" => Ok(QuantMethod::Obq),
        other => Err(crate::err!(
            "unknown quant method '{other}' (rtn|bitsplit|adaquant|adaround|obq)"
        )),
    }
}

// ----------------------------------------------------------------------
// Database + target specs
// ----------------------------------------------------------------------

/// Which kind of compression database a job references.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DbKind {
    /// Unstructured sparsity over a grid (Eq. 10).
    Sparsity,
    /// {8w8a, 4w4a} × {dense, 2:4} GPU scenario (Fig. 2).
    MixedGpu,
    /// AdaPrune+AdaQuant baseline variant of the GPU DB (App. A.11).
    MixedGpuBaseline,
    /// 4-block sparsity × int8 CPU scenario (Fig. 2d).
    Cpu,
}

impl DbKind {
    pub fn token(&self) -> &'static str {
        match self {
            DbKind::Sparsity => "sparsity",
            DbKind::MixedGpu => "mixed_gpu",
            DbKind::MixedGpuBaseline => "mixed_gpu_baseline",
            DbKind::Cpu => "cpu",
        }
    }

    pub fn parse(s: &str) -> Result<DbKind> {
        match s {
            "sparsity" => Ok(DbKind::Sparsity),
            "mixed_gpu" => Ok(DbKind::MixedGpu),
            "mixed_gpu_baseline" => Ok(DbKind::MixedGpuBaseline),
            "cpu" => Ok(DbKind::Cpu),
            other => Err(crate::err!(
                "unknown db kind '{other}' (sparsity|mixed_gpu|mixed_gpu_baseline|cpu)"
            )),
        }
    }
}

/// A database request: enough to build it — and to cache it, via
/// [`DbSpec::cache_key`]. Grid is ignored by the mixed-GPU kinds.
#[derive(Debug, Clone, PartialEq)]
pub struct DbSpec {
    pub kind: DbKind,
    pub method: PruneMethod,
    pub grid: Vec<f64>,
    pub scope: LayerScope,
}

impl DbSpec {
    /// Engine-cache key. Fields a kind hardwires are normalized out so
    /// the cache (and single-flight) cannot fragment across spellings
    /// of irrelevant fields: the mixed-GPU kinds ignore method AND grid
    /// (their levels are fixed by the paper's Fig. 2 setup), the CPU
    /// kind ignores method (always block-ExactOBS + int8).
    pub fn cache_key(&self) -> String {
        let token = prune_method_token(self.method);
        let (method, grid): (&str, &[f64]) = match self.kind {
            DbKind::Sparsity => (token.as_str(), &self.grid),
            DbKind::Cpu => ("fixed", &self.grid),
            DbKind::MixedGpu | DbKind::MixedGpuBaseline => ("fixed", &[]),
        };
        CompressionEngine::db_key(self.kind.token(), method, self.scope, grid)
    }
}

/// The constrained-resource axis of a solve job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TargetKind {
    /// FLOP-reduction factor over a sparsity DB.
    Flop,
    /// BOP-reduction factor over the mixed GPU DB.
    Bop,
    /// CPU latency speedup over the CPU DB.
    CpuTime,
}

impl TargetKind {
    pub fn token(&self) -> &'static str {
        match self {
            TargetKind::Flop => "flop",
            TargetKind::Bop => "bop",
            TargetKind::CpuTime => "cputime",
        }
    }

    pub fn parse(s: &str) -> Result<TargetKind> {
        match s {
            "flop" | "flops" => Ok(TargetKind::Flop),
            "bop" | "bops" => Ok(TargetKind::Bop),
            "cputime" | "latency" => Ok(TargetKind::CpuTime),
            other => Err(crate::err!("unknown target '{other}' (flop|bop|cputime)")),
        }
    }

    /// The database kind this target solves over by default.
    pub fn default_db(&self) -> DbKind {
        match self {
            TargetKind::Flop => DbKind::Sparsity,
            TargetKind::Bop => DbKind::MixedGpu,
            TargetKind::CpuTime => DbKind::Cpu,
        }
    }
}

// ----------------------------------------------------------------------
// JobSpec
// ----------------------------------------------------------------------

/// One compression job against a calibrated engine.
#[derive(Debug, Clone, PartialEq)]
pub enum JobSpec {
    /// Dense reference metric.
    Dense,
    /// Uniform unstructured pruning at one sparsity.
    Prune { method: PruneMethod, sparsity: f64, scope: LayerScope },
    /// N:M semi-structured pruning.
    Nm { method: PruneMethod, n: usize, m: usize, scope: LayerScope },
    /// Uniform weight quantization.
    Quant {
        method: QuantMethod,
        bits: u32,
        symmetric: bool,
        scope: LayerScope,
        corrected: bool,
    },
    /// Compound prune→quant: N:M prune then OBQ-quantize survivors.
    JointNmQuant { n: usize, m: usize, bits: u32, scope: LayerScope },
    /// Build (or warm) a compression database.
    BuildDb(DbSpec),
    /// Solve a resource target over a (cached) database and evaluate.
    Solve { db: DbSpec, target: TargetKind, value: f64 },
}

impl JobSpec {
    /// Wire op name.
    pub fn op(&self) -> &'static str {
        match self {
            JobSpec::Dense => "dense",
            JobSpec::Prune { .. } => "prune",
            JobSpec::Nm { .. } => "nm",
            JobSpec::Quant { .. } => "quant",
            JobSpec::JointNmQuant { .. } => "joint",
            JobSpec::BuildDb(_) => "db",
            JobSpec::Solve { .. } => "solve",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("op", self.op());
        match self {
            JobSpec::Dense => {}
            JobSpec::Prune { method, sparsity, scope } => {
                o.set("method", prune_method_token(*method))
                    .set("sparsity", *sparsity)
                    .set("scope", scope.as_str());
            }
            JobSpec::Nm { method, n, m, scope } => {
                o.set("method", prune_method_token(*method))
                    .set("n", *n)
                    .set("m", *m)
                    .set("scope", scope.as_str());
            }
            JobSpec::Quant { method, bits, symmetric, scope, corrected } => {
                o.set("method", quant_method_token(*method))
                    .set("bits", *bits)
                    .set("symmetric", *symmetric)
                    .set("corrected", *corrected)
                    .set("scope", scope.as_str());
            }
            JobSpec::JointNmQuant { n, m, bits, scope } => {
                o.set("n", *n).set("m", *m).set("bits", *bits).set("scope", scope.as_str());
            }
            JobSpec::BuildDb(db) => {
                set_db_fields(&mut o, db);
            }
            JobSpec::Solve { db, target, value } => {
                o.set("target", target.token()).set("value", *value);
                set_db_fields(&mut o, db);
            }
        }
        o
    }

    /// Decode from a parsed JSON object (the `op` field selects the
    /// variant; optional fields fall back to the CLI defaults).
    ///
    /// Numeric fields are **validated**, not `as`-cast: a fractional or
    /// out-of-range `n`/`m`/`bits`/`sparsity` is a typed parse error at
    /// the wire boundary instead of a kernel panic mid-job.
    pub fn from_json(j: &Json) -> Result<JobSpec> {
        let op = j.req_str("op")?;
        let scope_or = |default: LayerScope| -> Result<LayerScope> {
            match j.get("scope").and_then(|s| s.as_str()) {
                Some(s) => LayerScope::parse(s),
                None => Ok(default),
            }
        };
        match op {
            "dense" => Ok(JobSpec::Dense),
            "prune" => Ok(JobSpec::Prune {
                method: parse_prune_method(j.req_str("method")?)?,
                sparsity: req_sparsity(j)?,
                scope: scope_or(LayerScope::All)?,
            }),
            "nm" => {
                let (n, m) = req_nm(j)?;
                Ok(JobSpec::Nm {
                    method: match j.get("method").and_then(|v| v.as_str()) {
                        Some(v) => parse_prune_method(v)?,
                        None => PruneMethod::ExactObs,
                    },
                    n,
                    m,
                    scope: scope_or(LayerScope::SkipFirstLast)?,
                })
            }
            "quant" => Ok(JobSpec::Quant {
                method: parse_quant_method(j.req_str("method")?)?,
                bits: req_bits(j)?,
                symmetric: j.get("symmetric").and_then(|b| b.as_bool()).unwrap_or(false),
                corrected: j.get("corrected").and_then(|b| b.as_bool()).unwrap_or(true),
                scope: scope_or(LayerScope::All)?,
            }),
            "joint" => {
                let (n, m) = req_nm(j)?;
                Ok(JobSpec::JointNmQuant {
                    n,
                    m,
                    bits: req_bits(j)?,
                    scope: scope_or(LayerScope::SkipFirstLast)?,
                })
            }
            "db" => Ok(JobSpec::BuildDb(db_spec_from_json(j, DbKind::Sparsity)?)),
            "solve" => {
                let target = TargetKind::parse(j.req_str("target")?)?;
                let value = j.req_f64("value")?;
                if !value.is_finite() || value < 1.0 {
                    crate::bail!("solve 'value' must be a finite factor >= 1, got {value}");
                }
                Ok(JobSpec::Solve {
                    db: db_spec_from_json(j, target.default_db())?,
                    target,
                    value,
                })
            }
            other => Err(crate::err!("unknown job op '{other}'")),
        }
    }

    /// Canonical identity of a (model, spec) pair — the server's
    /// coalescing key. Deterministic: object keys serialize sorted.
    pub fn coalesce_key(&self, model: &str) -> String {
        format!("{model}|{}", self.to_json().to_string_compact())
    }

    /// The database this job builds or solves over, if any.
    pub fn db_spec(&self) -> Option<&DbSpec> {
        match self {
            JobSpec::BuildDb(db) => Some(db),
            JobSpec::Solve { db, .. } => Some(db),
            _ => None,
        }
    }

    /// Batch-scheduler admission-group key: database-backed jobs on the
    /// same (model, kind, method family, grid) can share one pooled
    /// build, so the layer scope is deliberately normalized OUT — the
    /// scheduler builds the union of the members' layer sets once and
    /// fans per-layer results back to each member's scope. `None` for
    /// jobs with no shareable database work (uniform runs, and the GMP
    /// flop-target solve, which threshold-searches without a database).
    pub fn batch_group_key(&self, model: &str) -> Option<String> {
        let db = self.db_spec()?;
        if matches!(self, JobSpec::Solve { target: TargetKind::Flop, .. })
            && db.kind == DbKind::Sparsity
            && db.method == PruneMethod::Gmp
        {
            return None;
        }
        let scopeless = DbSpec { scope: LayerScope::All, ..db.clone() };
        Some(format!("{model}|{}", scopeless.cache_key()))
    }
}

/// A required non-negative integer field (rejects fractional, negative,
/// non-finite and absurdly large values instead of saturating).
fn req_count(j: &Json, key: &str, min: usize) -> Result<usize> {
    let v = j.req_f64(key)?;
    if !v.is_finite() || v.fract() != 0.0 || v < min as f64 || v > 1e9 {
        crate::bail!("field '{key}' must be an integer >= {min}, got {v}");
    }
    Ok(v as usize)
}

fn req_nm(j: &Json) -> Result<(usize, usize)> {
    let n = req_count(j, "n", 1)?;
    let m = req_count(j, "m", 1)?;
    if n > m {
        crate::bail!("N:M pattern requires n <= m, got {n}:{m}");
    }
    Ok((n, m))
}

fn req_bits(j: &Json) -> Result<u32> {
    let b = req_count(j, "bits", 1)?;
    if b > 32 {
        crate::bail!("field 'bits' must be in 1..=32, got {b}");
    }
    Ok(b as u32)
}

fn req_sparsity(j: &Json) -> Result<f64> {
    let s = j.req_f64("sparsity")?;
    if !(0.0..=1.0).contains(&s) {
        crate::bail!("field 'sparsity' must be in [0, 1], got {s}");
    }
    Ok(s)
}

fn set_db_fields(o: &mut Json, db: &DbSpec) {
    o.set("kind", db.kind.token())
        .set("method", prune_method_token(db.method))
        .set("grid", db.grid.as_slice())
        .set("scope", db.scope.as_str());
}

fn db_spec_from_json(j: &Json, default_kind: DbKind) -> Result<DbSpec> {
    let kind = match j.get("kind").and_then(|k| k.as_str()) {
        Some(k) => DbKind::parse(k)?,
        None => default_kind,
    };
    let method = match j.get("method").and_then(|m| m.as_str()) {
        Some(m) => parse_prune_method(m)?,
        None => PruneMethod::ExactObs,
    };
    let grid = match j.get("grid").and_then(|g| g.as_arr()) {
        Some(arr) => {
            let mut grid = Vec::with_capacity(arr.len());
            for v in arr {
                let s = v.as_f64().ok_or_else(|| crate::err!("grid entries must be numbers"))?;
                if !(0.0..=1.0).contains(&s) {
                    crate::bail!("grid sparsities must be in [0, 1], got {s}");
                }
                grid.push(s);
            }
            grid
        }
        // Paper default: the Eq. 10 grid. Mixed-GPU kinds ignore it.
        None => crate::solver::sparsity_grid(0.1, 0.95),
    };
    let scope = match j.get("scope").and_then(|s| s.as_str()) {
        Some(s) => LayerScope::parse(s)?,
        None => match kind {
            DbKind::Sparsity => LayerScope::All,
            _ => LayerScope::SkipFirstLast,
        },
    };
    Ok(DbSpec { kind, method, grid, scope })
}

// ----------------------------------------------------------------------
// JobResult
// ----------------------------------------------------------------------

/// Outcome of a successfully executed job.
#[derive(Debug, Clone, PartialEq)]
pub enum JobResult {
    Dense { metric: f64 },
    Prune { method: String, sparsity: f64, metric: f64 },
    Nm { n: usize, m: usize, metric: f64 },
    Quant { method: String, bits: u32, metric: f64 },
    JointNmQuant { n: usize, m: usize, bits: u32, metric: f64 },
    /// `cached` is true when the database came from the engine cache.
    DbBuilt { kind: String, entries: usize, cached: bool },
    Solved { target: String, requested: f64, achieved: f64, metric: f64, cached_db: bool },
    Infeasible { target: String, requested: f64 },
}

impl JobResult {
    pub fn op(&self) -> &'static str {
        match self {
            JobResult::Dense { .. } => "dense",
            JobResult::Prune { .. } => "prune",
            JobResult::Nm { .. } => "nm",
            JobResult::Quant { .. } => "quant",
            JobResult::JointNmQuant { .. } => "joint",
            JobResult::DbBuilt { .. } => "db",
            JobResult::Solved { .. } | JobResult::Infeasible { .. } => "solve",
        }
    }

    /// The headline metric, when the job produced one.
    pub fn metric(&self) -> Option<f64> {
        match self {
            JobResult::Dense { metric }
            | JobResult::Prune { metric, .. }
            | JobResult::Nm { metric, .. }
            | JobResult::Quant { metric, .. }
            | JobResult::JointNmQuant { metric, .. }
            | JobResult::Solved { metric, .. } => Some(*metric),
            JobResult::DbBuilt { .. } | JobResult::Infeasible { .. } => None,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("op", self.op());
        match self {
            JobResult::Dense { metric } => {
                o.set("metric", *metric);
            }
            JobResult::Prune { method, sparsity, metric } => {
                o.set("method", method.as_str())
                    .set("sparsity", *sparsity)
                    .set("metric", *metric);
            }
            JobResult::Nm { n, m, metric } => {
                o.set("n", *n).set("m", *m).set("metric", *metric);
            }
            JobResult::Quant { method, bits, metric } => {
                o.set("method", method.as_str()).set("bits", *bits).set("metric", *metric);
            }
            JobResult::JointNmQuant { n, m, bits, metric } => {
                o.set("n", *n).set("m", *m).set("bits", *bits).set("metric", *metric);
            }
            JobResult::DbBuilt { kind, entries, cached } => {
                o.set("kind", kind.as_str()).set("entries", *entries).set("cached", *cached);
            }
            JobResult::Solved { target, requested, achieved, metric, cached_db } => {
                o.set("target", target.as_str())
                    .set("requested", *requested)
                    .set("achieved", *achieved)
                    .set("metric", *metric)
                    .set("cached_db", *cached_db);
            }
            JobResult::Infeasible { target, requested } => {
                o.set("target", target.as_str())
                    .set("requested", *requested)
                    .set("infeasible", true);
            }
        }
        o
    }

    pub fn from_json(j: &Json) -> Result<JobResult> {
        let op = j.req_str("op")?;
        match op {
            "dense" => Ok(JobResult::Dense { metric: j.req_f64("metric")? }),
            "prune" => Ok(JobResult::Prune {
                method: j.req_str("method")?.to_string(),
                sparsity: j.req_f64("sparsity")?,
                metric: j.req_f64("metric")?,
            }),
            "nm" => Ok(JobResult::Nm {
                n: req_count(j, "n", 1)?,
                m: req_count(j, "m", 1)?,
                metric: j.req_f64("metric")?,
            }),
            "quant" => Ok(JobResult::Quant {
                method: j.req_str("method")?.to_string(),
                bits: req_bits(j)?,
                metric: j.req_f64("metric")?,
            }),
            "joint" => Ok(JobResult::JointNmQuant {
                n: req_count(j, "n", 1)?,
                m: req_count(j, "m", 1)?,
                bits: req_bits(j)?,
                metric: j.req_f64("metric")?,
            }),
            "db" => Ok(JobResult::DbBuilt {
                kind: j.req_str("kind")?.to_string(),
                entries: req_count(j, "entries", 0)?,
                cached: j.get("cached").and_then(|b| b.as_bool()).unwrap_or(false),
            }),
            "solve" => {
                if j.get("infeasible").and_then(|b| b.as_bool()).unwrap_or(false) {
                    Ok(JobResult::Infeasible {
                        target: j.req_str("target")?.to_string(),
                        requested: j.req_f64("requested")?,
                    })
                } else {
                    Ok(JobResult::Solved {
                        target: j.req_str("target")?.to_string(),
                        requested: j.req_f64("requested")?,
                        achieved: j.req_f64("achieved")?,
                        metric: j.req_f64("metric")?,
                        cached_db: j.get("cached_db").and_then(|b| b.as_bool()).unwrap_or(false),
                    })
                }
            }
            other => Err(crate::err!("unknown result op '{other}'")),
        }
    }
}

// ----------------------------------------------------------------------
// Requests (jobs + control ops) — the line-protocol vocabulary
// ----------------------------------------------------------------------

/// Server control operations. Shutdown is a typed signal — the old
/// implementation abused an `ObcError` with the message "shutdown" as
/// control flow.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControlOp {
    /// Drain the queue, then stop.
    Shutdown,
    /// Liveness + registry summary.
    Health,
    /// Counter snapshot.
    Metrics,
    /// Counter snapshot rendered as Prometheus-style text (returned in
    /// the `text` field of a JSON line so the protocol stays
    /// line-oriented).
    MetricsProm,
    /// Flight-recorder dump: the bounded ring of recent serving events.
    Flight,
}

/// Admission priority class of a job (wire field `priority`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Priority {
    /// Latency-sensitive traffic: dequeued ahead of batch work and shed
    /// only at the full overload watermark.
    #[default]
    Interactive,
    /// Throughput traffic: sheds at half the depth watermark so
    /// interactive headroom survives saturation.
    Batch,
}

impl Priority {
    /// Stable wire token.
    pub fn token(&self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Result<Priority> {
        match s {
            "interactive" => Ok(Priority::Interactive),
            "batch" => Ok(Priority::Batch),
            other => Err(crate::err!("unknown priority '{other}' (interactive|batch)")),
        }
    }
}

/// One parsed request line.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Job {
        /// Client-supplied correlation id, echoed in the response.
        id: Option<String>,
        model: String,
        spec: JobSpec,
        /// Wall-clock budget in milliseconds: past it the server answers
        /// with a typed `"rejected":"deadline"` error instead of (or mid
        /// way through) executing. `None` = server default.
        deadline_ms: Option<u64>,
        /// Admission class (default interactive).
        priority: Priority,
        /// Per-job compute tier (wire field `precision`: `"f64"` or
        /// `"mixed"`). `None` defers to the server's global policy
        /// (`OBC_PRECISION`); the worker installs the override for the
        /// duration of the job and the response echoes the resolved
        /// tier.
        precision: Option<Precision>,
        /// Optional tenant label for per-tenant admission counting.
        tenant: Option<String>,
        /// Opt-in streaming: per-layer/per-level `{"chunk":...}` progress
        /// lines ahead of the final response.
        stream: bool,
        /// Opt-in profiling: the response carries a `profile` object
        /// with per-phase wall-ns for this job's execution.
        profile: bool,
    },
    Control(ControlOp),
}

impl Request {
    pub fn parse_line(line: &str) -> Result<Request> {
        let j = parse(line)?;
        let op = j.req_str("op")?;
        match op {
            "shutdown" => Ok(Request::Control(ControlOp::Shutdown)),
            "health" => Ok(Request::Control(ControlOp::Health)),
            "metrics" => Ok(Request::Control(ControlOp::Metrics)),
            "metrics_prom" => Ok(Request::Control(ControlOp::MetricsProm)),
            "flight" => Ok(Request::Control(ControlOp::Flight)),
            _ => Ok(Request::Job {
                id: j.get("id").and_then(|v| v.as_str()).map(|s| s.to_string()),
                model: j.req_str("model")?.to_string(),
                spec: JobSpec::from_json(&j)?,
                deadline_ms: match j.get("deadline_ms") {
                    None => None,
                    Some(v) => {
                        let ms = v.as_f64().ok_or_else(|| {
                            crate::err!("field 'deadline_ms' must be a number")
                        })?;
                        if !ms.is_finite() || ms < 0.0 || ms > 1e12 {
                            crate::bail!(
                                "field 'deadline_ms' must be a non-negative \
                                 number of milliseconds, got {ms}"
                            );
                        }
                        Some(ms as u64)
                    }
                },
                priority: match j.get("priority") {
                    None => Priority::Interactive,
                    Some(v) => {
                        let s = v.as_str().ok_or_else(|| {
                            crate::err!("field 'priority' must be a string")
                        })?;
                        Priority::parse(s)?
                    }
                },
                precision: match j.get("precision") {
                    None => None,
                    Some(v) => {
                        let s = v.as_str().ok_or_else(|| {
                            crate::err!("field 'precision' must be a string")
                        })?;
                        Some(Precision::parse(s).ok_or_else(|| {
                            crate::err!("unknown precision '{s}' (f64|mixed)")
                        })?)
                    }
                },
                tenant: j.get("tenant").and_then(|v| v.as_str()).map(|s| s.to_string()),
                stream: match j.get("stream") {
                    None => false,
                    Some(v) => v
                        .as_bool()
                        .ok_or_else(|| crate::err!("field 'stream' must be a boolean"))?,
                },
                profile: match j.get("profile") {
                    None => false,
                    Some(v) => v
                        .as_bool()
                        .ok_or_else(|| crate::err!("field 'profile' must be a boolean"))?,
                },
            }),
        }
    }
}

// ----------------------------------------------------------------------
// Execution
// ----------------------------------------------------------------------

/// Resolve a database spec through the engine's single-flight cache.
pub fn db_for_spec(engine: &CompressionEngine, spec: &DbSpec) -> Result<(Arc<ModelDb>, bool)> {
    engine.db_cached(&spec.cache_key(), || match spec.kind {
        DbKind::Sparsity => engine.build_sparsity_db(spec.method, &spec.grid, spec.scope),
        DbKind::MixedGpu => engine.build_mixed_gpu_db(spec.scope),
        DbKind::MixedGpuBaseline => engine.build_mixed_gpu_db_baseline(spec.scope),
        DbKind::Cpu => engine.build_cpu_db(&spec.grid, spec.scope),
    })
}

/// Execute one job against an engine. Pure with respect to the engine's
/// model state (jobs clone-and-stitch; they never mutate the dense
/// model), which is what makes concurrent execution and coalescing safe.
pub fn execute(engine: &CompressionEngine, spec: &JobSpec) -> Result<JobResult> {
    match spec {
        JobSpec::Dense => Ok(JobResult::Dense { metric: engine.dense_metric() }),
        JobSpec::Prune { method, sparsity, scope } => {
            let metric = engine.run_uniform_sparsity(*method, *sparsity, *scope)?;
            Ok(JobResult::Prune {
                method: prune_method_token(*method),
                sparsity: *sparsity,
                metric,
            })
        }
        JobSpec::Nm { method, n, m, scope } => {
            let metric = engine.run_nm(*method, *n, *m, *scope)?;
            Ok(JobResult::Nm { n: *n, m: *m, metric })
        }
        JobSpec::Quant { method, bits, symmetric, scope, corrected } => {
            let metric = engine.run_quant(*method, *bits, *symmetric, *scope, *corrected)?;
            Ok(JobResult::Quant {
                method: quant_method_token(*method).to_string(),
                bits: *bits,
                metric,
            })
        }
        JobSpec::JointNmQuant { n, m, bits, scope } => {
            let metric = engine.run_joint_nm_quant(*n, *m, *bits, *scope)?;
            Ok(JobResult::JointNmQuant { n: *n, m: *m, bits: *bits, metric })
        }
        JobSpec::BuildDb(db) => {
            let (built, cached) = db_for_spec(engine, db)?;
            Ok(JobResult::DbBuilt {
                kind: db.kind.token().to_string(),
                entries: built.len(),
                cached,
            })
        }
        JobSpec::Solve { db, target, value } => {
            // GMP has no per-layer solver — that is the point of the
            // baseline; it binary-searches a global threshold instead.
            // Only for the sparsity DB: an explicit cpu/mixed kind must
            // solve over its requested database (gmp is a no-op
            // spelling of `method` there), not silently switch paths.
            if *target == TargetKind::Flop
                && db.kind == DbKind::Sparsity
                && db.method == PruneMethod::Gmp
            {
                let (metric, achieved) = engine.eval_gmp_flop_target(db.scope, *value)?;
                return Ok(JobResult::Solved {
                    target: target.token().to_string(),
                    requested: *value,
                    achieved,
                    metric,
                    cached_db: false,
                });
            }
            let (built, cached) = db_for_spec(engine, db)?;
            let solved = match target {
                TargetKind::Flop => engine.eval_flop_target(&built, db.scope, *value),
                TargetKind::Bop => engine.eval_bop_target(&built, db.scope, *value),
                TargetKind::CpuTime => engine.eval_time_target(&built, db.scope, *value),
            };
            Ok(match solved {
                Some((metric, achieved)) => JobResult::Solved {
                    target: target.token().to_string(),
                    requested: *value,
                    achieved,
                    metric,
                    cached_db: cached,
                },
                None => JobResult::Infeasible {
                    target: target.token().to_string(),
                    requested: *value,
                },
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_specs() -> Vec<JobSpec> {
        vec![
            JobSpec::Dense,
            JobSpec::Prune {
                method: PruneMethod::ExactObs,
                sparsity: 0.6,
                scope: LayerScope::All,
            },
            JobSpec::Prune {
                method: PruneMethod::AdaPruneIter(4),
                sparsity: 0.5,
                scope: LayerScope::SkipFirstLast,
            },
            JobSpec::Nm {
                method: PruneMethod::ExactObs,
                n: 2,
                m: 4,
                scope: LayerScope::SkipFirstLast,
            },
            JobSpec::Quant {
                method: QuantMethod::Obq,
                bits: 4,
                symmetric: true,
                scope: LayerScope::All,
                corrected: false,
            },
            JobSpec::JointNmQuant { n: 2, m: 4, bits: 8, scope: LayerScope::SkipFirstLast },
            JobSpec::BuildDb(DbSpec {
                kind: DbKind::Sparsity,
                method: PruneMethod::ExactObs,
                grid: vec![0.0, 0.5, 0.75],
                scope: LayerScope::All,
            }),
            JobSpec::BuildDb(DbSpec {
                kind: DbKind::MixedGpu,
                method: PruneMethod::ExactObs,
                grid: vec![],
                scope: LayerScope::SkipFirstLast,
            }),
            JobSpec::Solve {
                db: DbSpec {
                    kind: DbKind::Cpu,
                    method: PruneMethod::ExactObs,
                    grid: vec![0.0, 0.5],
                    scope: LayerScope::SkipFirstLast,
                },
                target: TargetKind::CpuTime,
                value: 3.0,
            },
            JobSpec::Solve {
                db: DbSpec {
                    kind: DbKind::MixedGpuBaseline,
                    method: PruneMethod::AdaPrune,
                    grid: vec![],
                    scope: LayerScope::SkipFirstLast,
                },
                target: TargetKind::Bop,
                value: 8.0,
            },
        ]
    }

    fn all_results() -> Vec<JobResult> {
        vec![
            JobResult::Dense { metric: 82.5 },
            JobResult::Prune { method: "exactobs".into(), sparsity: 0.6, metric: 80.1 },
            JobResult::Nm { n: 2, m: 4, metric: 79.25 },
            JobResult::Quant { method: "obq".into(), bits: 4, metric: 81.0 },
            JobResult::JointNmQuant { n: 2, m: 4, bits: 8, metric: 78.5 },
            JobResult::DbBuilt { kind: "sparsity".into(), entries: 40, cached: true },
            JobResult::Solved {
                target: "flop".into(),
                requested: 2.0,
                achieved: 2.07,
                metric: 74.9,
                cached_db: true,
            },
            JobResult::Infeasible { target: "bop".into(), requested: 64.0 },
        ]
    }

    /// Every JobSpec variant round-trips through the wire codec.
    #[test]
    fn spec_roundtrip_all_variants() {
        for spec in all_specs() {
            let j = spec.to_json();
            let line = j.to_string_compact();
            let back = JobSpec::from_json(&parse(&line).unwrap())
                .unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(spec, back, "wire line: {line}");
        }
    }

    /// Every JobResult variant round-trips through the wire codec.
    #[test]
    fn result_roundtrip_all_variants() {
        for res in all_results() {
            let line = res.to_json().to_string_compact();
            let back = JobResult::from_json(&parse(&line).unwrap())
                .unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(res, back, "wire line: {line}");
        }
    }

    #[test]
    fn request_parses_jobs_and_control_ops() {
        let r = Request::parse_line(
            r#"{"id":"j1","model":"rneta","op":"prune","method":"exactobs","sparsity":0.5}"#,
        )
        .unwrap();
        match r {
            Request::Job {
                id,
                model,
                spec,
                deadline_ms,
                priority,
                precision,
                tenant,
                stream,
                profile,
            } => {
                assert_eq!(id.as_deref(), Some("j1"));
                assert_eq!(model, "rneta");
                assert_eq!(spec.op(), "prune");
                assert_eq!(deadline_ms, None);
                assert_eq!(priority, Priority::Interactive);
                assert_eq!(precision, None);
                assert_eq!(tenant, None);
                assert!(!stream);
                assert!(!profile);
            }
            _ => panic!("expected a job"),
        }
        match Request::parse_line(
            r#"{"model":"rneta","op":"dense","deadline_ms":2500}"#,
        )
        .unwrap()
        {
            Request::Job { deadline_ms, .. } => assert_eq!(deadline_ms, Some(2500)),
            _ => panic!("expected a job"),
        }
        match Request::parse_line(
            r#"{"model":"m","op":"dense","priority":"batch","tenant":"t7","stream":true}"#,
        )
        .unwrap()
        {
            Request::Job { priority, tenant, stream, .. } => {
                assert_eq!(priority, Priority::Batch);
                assert_eq!(tenant.as_deref(), Some("t7"));
                assert!(stream);
            }
            _ => panic!("expected a job"),
        }
        match Request::parse_line(r#"{"model":"m","op":"dense","profile":true}"#).unwrap() {
            Request::Job { profile, .. } => assert!(profile),
            _ => panic!("expected a job"),
        }
        match Request::parse_line(
            r#"{"model":"m","op":"dense","precision":"mixed"}"#,
        )
        .unwrap()
        {
            Request::Job { precision, .. } => {
                assert_eq!(precision, Some(Precision::Mixed));
            }
            _ => panic!("expected a job"),
        }
        for bad in [
            r#"{"model":"m","op":"dense","deadline_ms":"soon"}"#,
            r#"{"model":"m","op":"dense","deadline_ms":-5}"#,
            r#"{"model":"m","op":"dense","priority":"urgent"}"#,
            r#"{"model":"m","op":"dense","priority":7}"#,
            r#"{"model":"m","op":"dense","stream":"yes"}"#,
            r#"{"model":"m","op":"dense","precision":"half"}"#,
            r#"{"model":"m","op":"dense","precision":64}"#,
            r#"{"model":"m","op":"dense","profile":"yes"}"#,
        ] {
            assert!(Request::parse_line(bad).is_err(), "'{bad}' must be rejected");
        }
        assert_eq!(
            Request::parse_line(r#"{"op":"shutdown"}"#).unwrap(),
            Request::Control(ControlOp::Shutdown)
        );
        assert_eq!(
            Request::parse_line(r#"{"op":"health"}"#).unwrap(),
            Request::Control(ControlOp::Health)
        );
        assert_eq!(
            Request::parse_line(r#"{"op":"metrics"}"#).unwrap(),
            Request::Control(ControlOp::Metrics)
        );
        assert_eq!(
            Request::parse_line(r#"{"op":"metrics_prom"}"#).unwrap(),
            Request::Control(ControlOp::MetricsProm)
        );
        assert_eq!(
            Request::parse_line(r#"{"op":"flight"}"#).unwrap(),
            Request::Control(ControlOp::Flight)
        );
    }

    /// Malformed numeric fields fail at the wire boundary with a typed
    /// error — they never reach a kernel as a saturated cast.
    #[test]
    fn numeric_fields_are_validated_not_cast() {
        for bad in [
            r#"{"op":"nm","n":2,"m":0}"#,                     // m=0 → div-by-zero downstream
            r#"{"op":"nm","n":4,"m":2}"#,                     // n > m
            r#"{"op":"nm","n":1.5,"m":4}"#,                   // fractional
            r#"{"op":"joint","n":-2,"m":4,"bits":8}"#,        // negative
            r#"{"op":"quant","method":"obq","bits":-4}"#,     // negative bits
            r#"{"op":"quant","method":"obq","bits":64}"#,     // > 32
            r#"{"op":"prune","method":"gmp","sparsity":1.5}"#, // > 1
            r#"{"op":"solve","target":"flop","value":0.5}"#,  // factor < 1
            r#"{"op":"db","grid":[0.5,2.0]}"#,                // grid out of range
        ] {
            let j = parse(bad).unwrap();
            assert!(JobSpec::from_json(&j).is_err(), "'{bad}' must be rejected");
        }
        // The boundary values stay legal.
        for good in [
            r#"{"op":"nm","n":4,"m":4}"#,
            r#"{"op":"prune","method":"gmp","sparsity":1}"#,
            r#"{"op":"quant","method":"obq","bits":32}"#,
            r#"{"op":"solve","target":"flop","value":1}"#,
        ] {
            let j = parse(good).unwrap();
            assert!(JobSpec::from_json(&j).is_ok(), "'{good}' must parse");
        }
    }

    #[test]
    fn cache_key_normalizes_irrelevant_fields() {
        // The mixed-GPU kinds ignore method and grid: different
        // spellings must share one cache entry (and one build).
        let a = DbSpec {
            kind: DbKind::MixedGpu,
            method: PruneMethod::ExactObs,
            grid: vec![],
            scope: LayerScope::SkipFirstLast,
        };
        let b = DbSpec {
            kind: DbKind::MixedGpu,
            method: PruneMethod::Gmp,
            grid: vec![0.0, 0.5, 0.9],
            scope: LayerScope::SkipFirstLast,
        };
        assert_eq!(a.cache_key(), b.cache_key());
        // The CPU kind ignores method but NOT the grid.
        let cpu = |method, grid| DbSpec {
            kind: DbKind::Cpu,
            method,
            grid,
            scope: LayerScope::All,
        };
        let c1 = cpu(PruneMethod::ExactObs, vec![0.5]);
        let c2 = cpu(PruneMethod::Gmp, vec![0.5]);
        let c3 = cpu(PruneMethod::Gmp, vec![0.9]);
        assert_eq!(c1.cache_key(), c2.cache_key());
        assert_ne!(c2.cache_key(), c3.cache_key());
        // Sparsity keys on everything.
        let sp = |method| DbSpec {
            kind: DbKind::Sparsity,
            method,
            grid: vec![0.5],
            scope: LayerScope::All,
        };
        assert_ne!(sp(PruneMethod::ExactObs).cache_key(), sp(PruneMethod::Gmp).cache_key());
    }

    #[test]
    fn request_errors_are_typed_not_sentinel() {
        // Unknown ops and missing fields are plain errors; nothing
        // string-matches on the message for control flow anymore.
        assert!(Request::parse_line(r#"{"op":"explode","model":"x"}"#).is_err());
        assert!(Request::parse_line(r#"{"op":"prune"}"#).is_err()); // no model
        assert!(Request::parse_line("not json").is_err());
    }

    #[test]
    fn coalesce_key_is_canonical() {
        // Same logical job, different field order on the wire → same key.
        let a = JobSpec::from_json(
            &parse(r#"{"op":"prune","method":"exactobs","sparsity":0.5}"#).unwrap(),
        )
        .unwrap();
        let b = JobSpec::from_json(
            &parse(r#"{"sparsity":0.5,"method":"exactobs","op":"prune"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(a.coalesce_key("m"), b.coalesce_key("m"));
        assert_ne!(a.coalesce_key("m"), a.coalesce_key("other-model"));
    }

    #[test]
    fn batch_group_key_unions_scope_and_excludes_unshareable_jobs() {
        let db = |scope, method| DbSpec {
            kind: DbKind::Sparsity,
            method,
            grid: vec![0.0, 0.5, 0.9],
            scope,
        };
        // Same pooled build across scopes and across build-vs-solve...
        let build_all = JobSpec::BuildDb(db(LayerScope::All, PruneMethod::ExactObs));
        let solve_inner = JobSpec::Solve {
            db: db(LayerScope::SkipFirstLast, PruneMethod::ExactObs),
            target: TargetKind::Flop,
            value: 2.0,
        };
        assert_eq!(build_all.batch_group_key("m"), solve_inner.batch_group_key("m"));
        // ...but never across models, methods, or grids.
        assert_ne!(build_all.batch_group_key("m"), build_all.batch_group_key("m2"));
        let lobs = JobSpec::BuildDb(db(LayerScope::All, PruneMethod::Lobs));
        assert_ne!(build_all.batch_group_key("m"), lobs.batch_group_key("m"));
        // Jobs with no shareable database work never group: uniform runs
        // and the GMP flop solve (threshold search, no database).
        assert_eq!(JobSpec::Dense.batch_group_key("m"), None);
        let gmp_solve = JobSpec::Solve {
            db: db(LayerScope::All, PruneMethod::Gmp),
            target: TargetKind::Flop,
            value: 2.0,
        };
        assert_eq!(gmp_solve.batch_group_key("m"), None);
        // A GMP db *build* is real work and still groups.
        assert!(JobSpec::BuildDb(db(LayerScope::All, PruneMethod::Gmp))
            .batch_group_key("m")
            .is_some());
    }

    #[test]
    fn execute_runs_against_synthetic_engine() {
        let e = CompressionEngine::synthetic(7).unwrap();
        let r = execute(&e, &JobSpec::Dense).unwrap();
        assert!(matches!(r, JobResult::Dense { metric } if metric.is_finite()));
        let r = execute(
            &e,
            &JobSpec::Prune {
                method: PruneMethod::Gmp,
                sparsity: 0.5,
                scope: LayerScope::All,
            },
        )
        .unwrap();
        assert!(r.metric().unwrap().is_finite());
        // Solve twice over the same DB spec: second run hits the cache.
        let solve = JobSpec::Solve {
            db: DbSpec {
                kind: DbKind::Sparsity,
                method: PruneMethod::Gmp,
                grid: vec![0.0, 0.5, 0.9],
                scope: LayerScope::All,
            },
            target: TargetKind::Flop,
            value: 1.5,
        };
        let first = execute(&e, &solve).unwrap();
        let second = execute(&e, &solve).unwrap();
        match (&first, &second) {
            (JobResult::Solved { cached_db: c1, .. }, JobResult::Solved { cached_db: c2, .. }) => {
                assert!(!c1, "first solve builds");
                assert!(c2, "second solve must hit the engine cache");
            }
            other => panic!("expected two Solved results, got {other:?}"),
        }
    }
}
