//! Unified method dispatch: one enum covering the paper's method and
//! every baseline, so benches/tables select methods by name.

use crate::compress::baselines::{adaprune, adaquant, adaround, bitsplit, gmp, lobs};
use crate::compress::hessian::LayerHessian;
use crate::compress::exact_obs::ObsOpts;
use crate::compress::obq::{self, ObqOpts};
use crate::compress::quant::GridSearch;
use crate::compress::{exact_obs, sweep, CompressResult};
use crate::linalg::Mat;

/// Pruning method selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PruneMethod {
    Gmp,
    Lobs,
    AdaPrune,
    /// AdaPrune iterated k times (Appendix A.6).
    AdaPruneIter(usize),
    ExactObs,
}

impl PruneMethod {
    pub const ALL: [PruneMethod; 4] =
        [PruneMethod::Gmp, PruneMethod::Lobs, PruneMethod::AdaPrune, PruneMethod::ExactObs];

    pub fn name(&self) -> String {
        match self {
            PruneMethod::Gmp => "GMP".into(),
            PruneMethod::Lobs => "L-OBS".into(),
            PruneMethod::AdaPrune => "AdaPrune".into(),
            PruneMethod::AdaPruneIter(k) => format!("AdaPrune {k}x"),
            PruneMethod::ExactObs => "ExactOBS".into(),
        }
    }

    /// Unstructured pruning to a target sparsity.
    pub fn prune(&self, w: &Mat, h: &LayerHessian, sparsity: f64) -> CompressResult {
        match self {
            PruneMethod::Gmp => gmp::prune(w, h, sparsity),
            PruneMethod::Lobs => lobs::prune(w, h, sparsity),
            PruneMethod::AdaPrune => adaprune::prune(w, h, sparsity),
            PruneMethod::AdaPruneIter(k) => adaprune::prune_iterative(w, h, sparsity, *k),
            PruneMethod::ExactObs => {
                let opts = ObsOpts {
                    batch: sweep::configured_batch(),
                    precision: crate::util::precision::configured_precision(),
                    ..Default::default()
                };
                exact_obs::prune_unstructured(w, h, sparsity, &opts)
            }
        }
    }

    /// N:M pruning (only AdaPrune and ExactOBS support the pattern in the
    /// paper's tables).
    pub fn prune_nm(&self, w: &Mat, h: &LayerHessian, n: usize, m: usize) -> CompressResult {
        match self {
            PruneMethod::AdaPrune | PruneMethod::AdaPruneIter(_) => adaprune::prune_nm(w, h, n, m),
            PruneMethod::ExactObs => exact_obs::prune_nm(w, h, n, m),
            other => panic!("{} does not support N:M", other.name()),
        }
    }
}

/// Quantization method selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QuantMethod {
    Rtn,
    BitSplit,
    AdaQuant,
    AdaRound,
    Obq,
}

impl QuantMethod {
    pub const ALL: [QuantMethod; 5] = [
        QuantMethod::Rtn,
        QuantMethod::BitSplit,
        QuantMethod::AdaQuant,
        QuantMethod::AdaRound,
        QuantMethod::Obq,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            QuantMethod::Rtn => "RTN",
            QuantMethod::BitSplit => "BitSplit",
            QuantMethod::AdaQuant => "AdaQuant",
            QuantMethod::AdaRound => "AdaRound",
            QuantMethod::Obq => "OBQ",
        }
    }

    /// Quantize a full weight matrix (per-channel grids).
    pub fn quantize(
        &self,
        w: &Mat,
        h: &LayerHessian,
        bits: u32,
        symmetric: bool,
    ) -> CompressResult {
        match self {
            QuantMethod::Rtn => {
                let grids = crate::compress::quant::fit_grids_per_row(
                    w,
                    bits,
                    symmetric,
                    GridSearch::default(),
                );
                let mut out = w.clone();
                for r in 0..w.rows {
                    let q = crate::compress::quant::rtn(w.row(r), &grids[r]);
                    out.row_mut(r).copy_from_slice(&q);
                }
                let err = crate::compress::layer_sq_err(w, &out, &h.h);
                CompressResult::new(out, err)
            }
            QuantMethod::BitSplit => bitsplit::quantize(w, h, &bitsplit::BitSplitOpts::new(bits)),
            QuantMethod::AdaQuant => {
                let mut o = adaquant::AdaQuantOpts::new(bits);
                o.symmetric = symmetric;
                adaquant::quantize(w, h, &o)
            }
            QuantMethod::AdaRound => {
                let mut o = adaround::AdaRoundOpts::new(bits);
                o.symmetric = symmetric;
                adaround::quantize(w, h, &o)
            }
            QuantMethod::Obq => {
                let o = if symmetric { ObqOpts::symmetric(bits) } else { ObqOpts::new(bits) };
                obq::quantize(w, h, &o)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_stable() {
        assert_eq!(PruneMethod::ExactObs.name(), "ExactOBS");
        assert_eq!(PruneMethod::AdaPruneIter(4).name(), "AdaPrune 4x");
        assert_eq!(QuantMethod::Obq.name(), "OBQ");
    }

    #[test]
    fn all_prune_methods_run() {
        let w = Mat::randn(4, 16, 1);
        let h = LayerHessian::synthetic(16, 2);
        for m in PruneMethod::ALL {
            let r = m.prune(&w, &h, 0.5);
            assert!((r.sparsity - 0.5).abs() < 0.05, "{}: {}", m.name(), r.sparsity);
            assert!(r.sq_err.is_finite());
        }
    }

    #[test]
    fn all_quant_methods_run() {
        let w = Mat::randn(4, 16, 3);
        let h = LayerHessian::synthetic(16, 4);
        for m in QuantMethod::ALL {
            let r = m.quantize(&w, &h, 4, false);
            assert!(r.sq_err.is_finite(), "{}", m.name());
        }
    }
}
