//! Kernel dispatch: PJRT-executed AOT artifacts when the `pjrt` feature
//! is enabled and an artifact covers the problem shape, native Rust
//! kernels otherwise. Both paths compute the same algorithms; the native
//! path is the reference and is locked down by the conformance tests
//! (`rust/tests/kernel_conformance.rs`), the PJRT path is cross-checked
//! against it by `rust/tests/runtime_bridge.rs`.

use crate::compress::exact_obs::RowTrace;
use crate::compress::hessian::HessianAccumulator;
use crate::compress::quant::Grid;
use crate::compress::sweep::{self, NonSpd};
use crate::linalg::Mat;
use crate::util::error::Result;
use crate::util::pool;
use crate::util::scratch;
use std::sync::Arc;

/// Result of an OBS sweep over a batch of rows.
pub struct SweepOut {
    pub w: Mat,
    pub traces: Vec<RowTrace>,
}

// ----------------------------------------------------------------------
// Dispatch entry points: artifact-backed when possible, native otherwise.
// ----------------------------------------------------------------------

/// Full-trace ExactOBS sweep of every row of `w` against the shared
/// initial H⁻¹. Uses a PJRT artifact when the `pjrt` feature is on and
/// the manifest covers (rows, d); otherwise runs the native kernels.
///
/// Convenience entry point: under `pjrt` it builds a fresh Runtime per
/// call (client start + artifact compile, no executable-cache reuse)
/// and silently falls back to native when that fails. Perf-sensitive
/// callers should hold a `runtime::Runtime` and call
/// `pjrt::obs_sweep_pjrt` directly to amortize compilation.
pub fn obs_sweep(w: &Mat, hinv: &Mat) -> Result<SweepOut> {
    #[cfg(feature = "pjrt")]
    {
        if let Ok(rt) = super::Runtime::new() {
            if let Some(res) = pjrt::obs_sweep_pjrt(&rt, w, hinv) {
                return res;
            }
        }
    }
    Ok(obs_sweep_native(w, hinv))
}

/// OBQ sweep of every row with per-row grids. PJRT artifacts only cover
/// the 4-bit grid (maxq = 15); anything else goes native directly.
pub fn obq_sweep(w: &Mat, hinv: &Mat, grids: &[Grid]) -> Result<Mat> {
    #[cfg(feature = "pjrt")]
    {
        if grids.iter().all(|g| g.maxq == 15.0) {
            if let Ok(rt) = super::Runtime::new() {
                let pairs: Vec<(f64, f64)> =
                    grids.iter().map(|g| (g.scale, g.zero)).collect();
                if let Some(res) = pjrt::obq_sweep_pjrt(&rt, w, hinv, &pairs) {
                    return res;
                }
            }
        }
    }
    Ok(obq_sweep_native(w, hinv, grids))
}

/// Layer Hessian H = 2XXᵀ for X of shape d × n.
pub fn hessian(x: &Mat) -> Result<Mat> {
    #[cfg(feature = "pjrt")]
    {
        if let Ok(rt) = super::Runtime::new() {
            if let Some(res) = pjrt::hessian_pjrt(&rt, x) {
                return res;
            }
        }
    }
    Ok(hessian_native(x))
}

// ----------------------------------------------------------------------
// Native kernels (always available; the conformance reference).
// ----------------------------------------------------------------------

/// Native full-trace OBS sweep: one Algorithm-1 arena job per row on
/// the shared pool (worker scratch, zero steady-state allocation),
/// stitched in row order. Only the raw H⁻¹ is available here — there is
/// no layer H to re-damp — so non-SPD corruption panics on the CALLING
/// thread with the diag context (callers own the dampening policy),
/// instead of dying inside a pool worker.
pub fn obs_sweep_native(w: &Mat, hinv: &Mat) -> SweepOut {
    let d = w.cols;
    let rows = w.rows;
    let wa = Arc::new(w.clone());
    let ha = Arc::new(hinv.clone());
    let per_row: Vec<std::result::Result<(Vec<f64>, RowTrace), NonSpd>> =
        pool::global().par_map(rows, move |r| {
            scratch::with(|s| {
                sweep::prune_sweep(s, wa.row(r), &ha, d, |_, _| true)?;
                let t =
                    RowTrace { order: s.trace_order.clone(), dloss: s.trace_dloss.clone() };
                Ok((s.out()[..d].to_vec(), t))
            })
        });
    let mut out = Mat::zeros(rows, d);
    let mut traces = Vec::with_capacity(rows);
    for (r, res) in per_row.into_iter().enumerate() {
        let (wr, t) = res.unwrap_or_else(|e| {
            panic!("obs_sweep_native row {r}: {e}; re-finalize the Hessian with more dampening")
        });
        out.row_mut(r).copy_from_slice(&wr);
        traces.push(t);
    }
    SweepOut { w: out, traces }
}

/// Native OBQ sweep (Algorithm 3 with the outlier heuristic, matching
/// the AOT artifact semantics) over all rows, per-row grids. Same
/// arena + loud-on-calling-thread non-SPD policy as [`obs_sweep_native`].
pub fn obq_sweep_native(w: &Mat, hinv: &Mat, grids: &[Grid]) -> Mat {
    assert_eq!(grids.len(), w.rows);
    let d = w.cols;
    let rows = w.rows;
    let wa = Arc::new(w.clone());
    let ha = Arc::new(hinv.clone());
    let grids = Arc::new(grids.to_vec());
    let per_row: Vec<std::result::Result<Vec<f64>, NonSpd>> =
        pool::global().par_map(rows, move |r| {
            scratch::with(|s| {
                sweep::quant_sweep(s, wa.row(r), &ha, &grids[r], true)?;
                Ok(s.out()[..d].to_vec())
            })
        });
    let mut out = Mat::zeros(rows, d);
    for (r, res) in per_row.into_iter().enumerate() {
        let q = res.unwrap_or_else(|e| {
            panic!("obq_sweep_native row {r}: {e}; re-finalize the Hessian with more dampening")
        });
        out.row_mut(r).copy_from_slice(&q);
    }
    out
}

/// Native Hessian: the streaming accumulator's 2XXᵀ.
pub fn hessian_native(x: &Mat) -> Mat {
    let mut acc = HessianAccumulator::new(x.rows);
    acc.add_batch(x);
    acc.raw()
}

// ----------------------------------------------------------------------
// PJRT-backed execution (feature `pjrt` only).
// ----------------------------------------------------------------------

#[cfg(feature = "pjrt")]
pub mod pjrt {
    use super::{Result, SweepOut};
    use crate::compress::exact_obs::RowTrace;
    use crate::linalg::Mat;
    use crate::runtime::Runtime;

    /// Run the full ExactOBS trace sweep on `w` (rows × d) with shared
    /// initial inverse Hessian through a PJRT artifact. Rows are padded up to
    /// the artifact's row count with zeros (rows are independent, so padding
    /// is sound). Returns None when no artifact covers d.
    pub fn obs_sweep_pjrt(rt: &Runtime, w: &Mat, hinv: &Mat) -> Option<Result<SweepOut>> {
        let d = w.cols;
        let art = rt.manifest.find_sweep("obs_sweep", w.rows, d)?;
        if art.rows < w.rows {
            // Run in row-chunks of the artifact size.
            let mut traces = Vec::with_capacity(w.rows);
            let mut out = Mat::zeros(w.rows, d);
            let mut r0 = 0;
            while r0 < w.rows {
                let r1 = (r0 + art.rows).min(w.rows);
                let chunk =
                    w.submatrix(&(r0..r1).collect::<Vec<_>>(), &(0..d).collect::<Vec<_>>());
                match run_chunk(rt, &art.name, art.rows, &chunk, hinv) {
                    Ok(mut res) => {
                        for (i, r) in (r0..r1).enumerate() {
                            out.row_mut(r).copy_from_slice(res.w.row(i));
                        }
                        traces.extend(res.traces.drain(..r1 - r0));
                    }
                    Err(e) => return Some(Err(e)),
                }
                r0 = r1;
            }
            return Some(Ok(SweepOut { w: out, traces }));
        }
        Some(run_chunk(rt, &art.name, art.rows, w, hinv).map(|mut res| {
            res.traces.truncate(w.rows);
            let keep: Vec<usize> = (0..w.rows).collect();
            let all: Vec<usize> = (0..d).collect();
            SweepOut { w: res.w.submatrix(&keep, &all), traces: res.traces }
        }))
    }

    fn run_chunk(
        rt: &Runtime,
        artifact: &str,
        art_rows: usize,
        w: &Mat,
        hinv: &Mat,
    ) -> Result<SweepOut> {
        let d = w.cols;
        // Pad rows with zeros to the artifact shape.
        let mut win = vec![0.0f32; art_rows * d];
        for r in 0..w.rows {
            for c in 0..d {
                win[r * d + c] = w.at(r, c) as f32;
            }
        }
        let hin: Vec<f32> = hinv.data.iter().map(|&v| v as f32).collect();
        let outs = rt.run_f32(
            artifact,
            &[(&win, &[art_rows as i64, d as i64]), (&hin, &[d as i64, d as i64])],
        )?;
        crate::ensure!(outs.len() == 3, "obs_sweep artifact returned {} outputs", outs.len());
        let (wout, order, dloss) = (&outs[0], &outs[1], &outs[2]);
        let mut out_w = Mat::zeros(art_rows, d);
        for i in 0..art_rows * d {
            out_w.data[i] = wout[i] as f64;
        }
        let traces = (0..art_rows)
            .map(|r| {
                let mut t = RowTrace { order: Vec::new(), dloss: Vec::new() };
                for c in 0..d {
                    let idx = order[r * d + c];
                    if idx < 0.0 {
                        break;
                    }
                    t.order.push(idx as usize);
                    t.dloss.push(dloss[r * d + c] as f64);
                }
                t
            })
            .collect();
        Ok(SweepOut { w: out_w, traces })
    }

    /// OBQ sweep through PJRT (4-bit artifact grid; maxq = 15). `grids` is
    /// rows × 2 (scale, zero). Returns None when no artifact covers the
    /// shape.
    pub fn obq_sweep_pjrt(
        rt: &Runtime,
        w: &Mat,
        hinv: &Mat,
        grids: &[(f64, f64)],
    ) -> Option<Result<Mat>> {
        let d = w.cols;
        let art = rt.manifest.find_sweep("obq_sweep", w.rows, d)?;
        if art.rows < w.rows {
            return None; // chunking analogous to obs; not needed for tests
        }
        let mut win = vec![0.0f32; art.rows * d];
        for r in 0..w.rows {
            for c in 0..d {
                win[r * d + c] = w.at(r, c) as f32;
            }
        }
        let mut gin = vec![0.0f32; art.rows * 2];
        for (r, (s, z)) in grids.iter().enumerate() {
            gin[r * 2] = *s as f32;
            gin[r * 2 + 1] = *z as f32;
        }
        // Padded rows get a unit grid to avoid 0-scale degeneracy.
        for r in grids.len()..art.rows {
            gin[r * 2] = 1.0;
        }
        let hin: Vec<f32> = hinv.data.iter().map(|&v| v as f32).collect();
        let res = rt.run_f32(
            &art.name,
            &[
                (&win, &[art.rows as i64, d as i64]),
                (&hin, &[d as i64, d as i64]),
                (&gin, &[art.rows as i64, 2]),
            ],
        );
        Some(res.map(|outs| {
            let wout = &outs[0];
            let mut m = Mat::zeros(w.rows, d);
            for r in 0..w.rows {
                for c in 0..d {
                    m.data[r * d + c] = wout[r * d + c] as f64;
                }
            }
            m
        }))
    }

    /// Hessian 2XXᵀ through PJRT (shape must match an artifact exactly).
    pub fn hessian_pjrt(rt: &Runtime, x: &Mat) -> Option<Result<Mat>> {
        let art = rt
            .manifest
            .kernels
            .iter()
            .find(|k| k.kind == "hessian" && k.d == x.rows && k.n == x.cols)?;
        let xin: Vec<f32> = x.data.iter().map(|&v| v as f32).collect();
        let res = rt.run_f32(&art.name, &[(&xin, &[x.rows as i64, x.cols as i64])]);
        Some(res.map(|outs| {
            let h = &outs[0];
            let mut m = Mat::zeros(x.rows, x.rows);
            for i in 0..x.rows * x.rows {
                m.data[i] = h[i] as f64;
            }
            m
        }))
    }
}
