//! Kernel dispatch: PJRT-executed AOT artifacts when the problem shape is
//! covered, native Rust otherwise. The two paths compute the same
//! algorithm and are cross-checked by integration tests
//! (`rust/tests/runtime_bridge.rs`).

use super::Runtime;
use crate::compress::exact_obs::RowTrace;
use crate::linalg::Mat;

/// Result of an OBS sweep over a batch of rows.
pub struct SweepOut {
    pub w: Mat,
    pub traces: Vec<RowTrace>,
}

/// Run the full ExactOBS trace sweep on `w` (rows × d) with shared
/// initial inverse Hessian through a PJRT artifact. Rows are padded up to
/// the artifact's row count with zeros (rows are independent, so padding
/// is sound). Returns None when no artifact covers d.
pub fn obs_sweep_pjrt(rt: &Runtime, w: &Mat, hinv: &Mat) -> Option<anyhow::Result<SweepOut>> {
    let d = w.cols;
    let art = rt.manifest.find_sweep("obs_sweep", w.rows, d)?;
    if art.rows < w.rows {
        // Run in row-chunks of the artifact size.
        let mut traces = Vec::with_capacity(w.rows);
        let mut out = Mat::zeros(w.rows, d);
        let mut r0 = 0;
        while r0 < w.rows {
            let r1 = (r0 + art.rows).min(w.rows);
            let chunk = w.submatrix(&(r0..r1).collect::<Vec<_>>(), &(0..d).collect::<Vec<_>>());
            match run_chunk(rt, &art.name, art.rows, &chunk, hinv) {
                Ok(mut res) => {
                    for (i, r) in (r0..r1).enumerate() {
                        out.row_mut(r).copy_from_slice(res.w.row(i));
                    }
                    traces.extend(res.traces.drain(..r1 - r0));
                }
                Err(e) => return Some(Err(e)),
            }
            r0 = r1;
        }
        return Some(Ok(SweepOut { w: out, traces }));
    }
    Some(run_chunk(rt, &art.name, art.rows, w, hinv).map(|mut res| {
        res.traces.truncate(w.rows);
        let keep: Vec<usize> = (0..w.rows).collect();
        let all: Vec<usize> = (0..d).collect();
        SweepOut { w: res.w.submatrix(&keep, &all), traces: res.traces }
    }))
}

fn run_chunk(
    rt: &Runtime,
    artifact: &str,
    art_rows: usize,
    w: &Mat,
    hinv: &Mat,
) -> anyhow::Result<SweepOut> {
    let d = w.cols;
    // Pad rows with zeros to the artifact shape.
    let mut win = vec![0.0f32; art_rows * d];
    for r in 0..w.rows {
        for c in 0..d {
            win[r * d + c] = w.at(r, c) as f32;
        }
    }
    let hin: Vec<f32> = hinv.data.iter().map(|&v| v as f32).collect();
    let outs = rt.run_f32(
        artifact,
        &[(&win, &[art_rows as i64, d as i64]), (&hin, &[d as i64, d as i64])],
    )?;
    anyhow::ensure!(outs.len() == 3, "obs_sweep artifact returned {} outputs", outs.len());
    let (wout, order, dloss) = (&outs[0], &outs[1], &outs[2]);
    let mut out_w = Mat::zeros(art_rows, d);
    for i in 0..art_rows * d {
        out_w.data[i] = wout[i] as f64;
    }
    let traces = (0..art_rows)
        .map(|r| {
            let mut t = RowTrace { order: Vec::new(), dloss: Vec::new() };
            for c in 0..d {
                let idx = order[r * d + c];
                if idx < 0.0 {
                    break;
                }
                t.order.push(idx as usize);
                t.dloss.push(dloss[r * d + c] as f64);
            }
            t
        })
        .collect();
    Ok(SweepOut { w: out_w, traces })
}

/// OBQ sweep through PJRT (4-bit artifact grid; maxq = 15). `grids` is
/// rows × 2 (scale, zero). Returns None when no artifact covers the
/// shape.
pub fn obq_sweep_pjrt(
    rt: &Runtime,
    w: &Mat,
    hinv: &Mat,
    grids: &[(f64, f64)],
) -> Option<anyhow::Result<Mat>> {
    let d = w.cols;
    let art = rt.manifest.find_sweep("obq_sweep", w.rows, d)?;
    if art.rows < w.rows {
        return None; // chunking analogous to obs; not needed for tests
    }
    let mut win = vec![0.0f32; art.rows * d];
    for r in 0..w.rows {
        for c in 0..d {
            win[r * d + c] = w.at(r, c) as f32;
        }
    }
    let mut gin = vec![0.0f32; art.rows * 2];
    for (r, (s, z)) in grids.iter().enumerate() {
        gin[r * 2] = *s as f32;
        gin[r * 2 + 1] = *z as f32;
    }
    // Padded rows get a unit grid to avoid 0-scale degeneracy.
    for r in grids.len()..art.rows {
        gin[r * 2] = 1.0;
    }
    let hin: Vec<f32> = hinv.data.iter().map(|&v| v as f32).collect();
    let res = rt.run_f32(
        &art.name,
        &[
            (&win, &[art.rows as i64, d as i64]),
            (&hin, &[d as i64, d as i64]),
            (&gin, &[art.rows as i64, 2]),
        ],
    );
    Some(res.map(|outs| {
        let wout = &outs[0];
        let mut m = Mat::zeros(w.rows, d);
        for r in 0..w.rows {
            for c in 0..d {
                m.data[r * d + c] = wout[r * d + c] as f64;
            }
        }
        m
    }))
}

/// Hessian 2XXᵀ through PJRT (shape must match an artifact exactly).
pub fn hessian_pjrt(rt: &Runtime, x: &Mat) -> Option<anyhow::Result<Mat>> {
    let art = rt
        .manifest
        .kernels
        .iter()
        .find(|k| k.kind == "hessian" && k.d == x.rows && k.n == x.cols)?;
    let xin: Vec<f32> = x.data.iter().map(|&v| v as f32).collect();
    let res = rt.run_f32(&art.name, &[(&xin, &[x.rows as i64, x.cols as i64])]);
    Some(res.map(|outs| {
        let h = &outs[0];
        let mut m = Mat::zeros(x.rows, x.rows);
        for i in 0..x.rows * x.rows {
            m.data[i] = h[i] as f64;
        }
        m
    }))
}
