//! Kernel runtime: the artifact manifest plus the PJRT bridge.
//!
//! The manifest (`artifacts/manifest.json`, produced by the build-time
//! JAX/Pallas layer `python/compile/aot.py`) parses with the in-tree
//! JSON substrate and is available in every build. The PJRT execution
//! path — loading AOT-compiled HLO **text** artifacts and running them
//! through an `xla` binding — is compiled only with the off-by-default
//! `pjrt` cargo feature; without it, [`dispatch`] falls through to the
//! native Rust kernels (same algorithms, cross-checked by the
//! conformance tests in `rust/tests/`).
//!
//! Interchange format is HLO text — the image's xla_extension 0.5.1
//! rejects jax≥0.5 serialized protos (64-bit instruction ids); the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod dispatch;

use crate::util::error::Result;
use crate::util::io::{artifacts_dir, read_to_string};
use crate::util::json::{parse, Json};
use std::path::PathBuf;

/// One artifact as described in `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct KernelArtifact {
    pub name: String,
    pub kind: String,
    pub rows: usize,
    pub d: usize,
    pub n: usize,
    pub file: String,
}

/// Parsed manifest.
pub struct Manifest {
    pub kernels: Vec<KernelArtifact>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load from the artifacts directory; Err if artifacts were not built.
    pub fn load() -> Result<Manifest> {
        let dir = artifacts_dir();
        let text = read_to_string(&dir.join("manifest.json"))?;
        let root = parse(&text)?;
        let mut kernels = Vec::new();
        if let Some(arr) = root.get("kernels").and_then(Json::as_arr) {
            for k in arr {
                kernels.push(KernelArtifact {
                    name: k.req_str("name")?.to_string(),
                    kind: k.req_str("kind")?.to_string(),
                    rows: k.get("rows").and_then(Json::as_usize).unwrap_or(0),
                    d: k.get("d").and_then(Json::as_usize).unwrap_or(0),
                    n: k.get("n").and_then(Json::as_usize).unwrap_or(0),
                    file: k.req_str("file")?.to_string(),
                });
            }
        }
        Ok(Manifest { kernels, dir })
    }

    /// Find the best obs/obq artifact for a (rows, d) problem: exact d
    /// match with artifact rows ≥ requested rows is required (rows are
    /// padded up by the dispatcher).
    pub fn find_sweep(&self, kind: &str, rows: usize, d: usize) -> Option<&KernelArtifact> {
        self.kernels
            .iter()
            .filter(|k| k.kind == kind && k.d == d && k.rows >= rows.min(k.rows))
            .min_by_key(|k| k.rows)
            .filter(|k| k.d == d)
    }

    pub fn find(&self, name: &str) -> Option<&KernelArtifact> {
        self.kernels.iter().find(|k| k.name == name)
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_backend::Runtime;

/// PJRT CPU execution of AOT artifacts. Requires a locally-vendored
/// `xla` binding crate (see the `pjrt` feature notes in Cargo.toml).
#[cfg(feature = "pjrt")]
mod pjrt_backend {
    use super::Manifest;
    use crate::util::error::Result;
    use std::collections::HashMap;
    use std::sync::Mutex;

    /// A PJRT CPU client with an executable cache, keyed by artifact name.
    pub struct Runtime {
        pub client: xla::PjRtClient,
        pub manifest: Manifest,
        cache: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
    }

    impl Runtime {
        /// Create the runtime (loads the manifest, starts the CPU client).
        pub fn new() -> Result<Runtime> {
            let manifest = Manifest::load()?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| crate::err!("PJRT CPU client: {e}"))?;
            Ok(Runtime { client, manifest, cache: Mutex::new(HashMap::new()) })
        }

        /// Compile an artifact (cached; PjRtLoadedExecutable is not Clone, so
        /// execution happens under the cache lock — fine on this single-core
        /// testbed, and compilation dominates anyway).
        fn with_executable<T>(
            &self,
            name: &str,
            f: impl FnOnce(&xla::PjRtLoadedExecutable) -> Result<T>,
        ) -> Result<T> {
            let mut cache = self.cache.lock().unwrap();
            if !cache.contains_key(name) {
                let art = self
                    .manifest
                    .find(name)
                    .ok_or_else(|| crate::err!("artifact '{name}' not in manifest"))?;
                let path = self.manifest.dir.join(&art.file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().expect("artifact path utf-8"),
                )
                .map_err(|e| crate::err!("parse {}: {e}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| crate::err!("compile {name}: {e}"))?;
                cache.insert(name.to_string(), exe);
            }
            f(cache.get(name).unwrap())
        }

        /// Execute an artifact on f32 inputs with given shapes. Returns the
        /// flattened f32 outputs of the result tuple.
        pub fn run_f32(
            &self,
            name: &str,
            inputs: &[(&[f32], &[i64])],
        ) -> Result<Vec<Vec<f32>>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, dims)| {
                    xla::Literal::vec1(data)
                        .reshape(dims)
                        .map_err(|e| crate::err!("reshape input: {e}"))
                })
                .collect::<Result<Vec<_>>>()?;
            let result = self.with_executable(name, |exe| {
                exe.execute::<xla::Literal>(&literals)
                    .map_err(|e| crate::err!("execute {name}: {e}"))?[0][0]
                    .to_literal_sync()
                    .map_err(|e| crate::err!("fetch result: {e}"))
            })?;
            let parts = result
                .to_tuple()
                .map_err(|e| crate::err!("untuple: {e}"))?;
            parts
                .into_iter()
                .map(|lit| {
                    // Outputs may be f32 or s32; convert s32 → f32 via i32 vec.
                    match lit.ty() {
                        Ok(xla::ElementType::S32) => {
                            let v = lit
                                .to_vec::<i32>()
                                .map_err(|e| crate::err!("to_vec<i32>: {e}"))?;
                            Ok(v.into_iter().map(|x| x as f32).collect())
                        }
                        _ => lit
                            .to_vec::<f32>()
                            .map_err(|e| crate::err!("to_vec<f32>: {e}")),
                    }
                })
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_shape() {
        // Build a fake manifest in a temp dir and point the artifacts
        // root at it via the thread-scoped override (not
        // `env::set_var`, which races concurrent `env::var` readers in
        // parallel tests).
        let dir = std::env::temp_dir().join("obc_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"kernels": [
                {"name": "obs_sweep_r8_d16", "kind": "obs_sweep", "rows": 8, "d": 16, "file": "x.hlo.txt"},
                {"name": "hessian_d32_n128", "kind": "hessian", "d": 32, "n": 128, "file": "y.hlo.txt"}
            ]}"#,
        )
        .unwrap();
        let _artifacts = crate::util::io::override_artifacts_dir(dir.clone());
        let m = Manifest::load().unwrap();
        assert_eq!(m.kernels.len(), 2);
        assert!(m.find("obs_sweep_r8_d16").is_some());
        let k = m.find_sweep("obs_sweep", 4, 16).unwrap();
        assert_eq!(k.rows, 8);
        assert!(m.find_sweep("obs_sweep", 4, 99).is_none());
    }
}
