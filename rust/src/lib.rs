//! # OBC — Optimal Brain Compression
//!
//! A production-grade reproduction of *"Optimal Brain Compression: A
//! Framework for Accurate Post-Training Quantization and Pruning"*
//! (Frantar & Alistarh, NeurIPS 2022).
//!
//! The crate implements the full OBC system:
//!
//! * [`compress`] — the paper's contribution: **ExactOBS** (Algorithm 1 +
//!   the global step Algorithm 2, N:M and block-sparsity variants) and
//!   **OBQ** (Algorithm 3 + outlier heuristic), plus every baseline the
//!   paper compares against (GMP, L-OBS, AdaPrune, global AdaPrune,
//!   AdaQuant, BitSplit, AdaRound-style).
//! * [`nn`] / [`data`] — a self-contained inference engine and synthetic
//!   dataset substrate standing in for the paper's ImageNet/COCO/SQuAD
//!   models (see DESIGN.md §2 for the substitution argument).
//! * [`db`] + [`solver`] + [`cost`] — the non-uniform compression pipeline:
//!   model database, SPDY-style DP solver, FLOP/BOP/CPU-latency models.
//! * [`store`] — the disk-backed snapshot store: versioned, checksummed
//!   binary snapshots of built trace databases (write-through on build,
//!   fingerprint-validated warm start on restart, quarantine-on-corrupt).
//! * [`stats`] — batch-norm reset and mean/variance correction (Eq. 9).
//! * [`coordinator`] — the L3 orchestration layer: the shared
//!   [`coordinator::engine::CompressionEngine`] (bundle + Hessians +
//!   memoized databases behind `Arc`), the typed job vocabulary
//!   ([`coordinator::jobs`]), and the `Pipeline` compatibility facade.
//! * [`server`] — the concurrent compression service: bounded request
//!   queue, per-model registry with single-flight calibration, job
//!   coalescing, metrics, and the line protocol behind
//!   `examples/serve_compress.rs` / `obc serve`.
//! * [`runtime`] — kernel dispatch. By default every kernel runs on the
//!   native Rust implementations, with the per-row ExactOBS/OBQ sweeps
//!   fanned out over the shared in-tree thread pool (`util::pool`) —
//!   deterministic, bit-identical to serial. The PJRT path (AOT-compiled
//!   HLO artifacts from the build-time JAX/Pallas layer) sits behind the
//!   off-by-default `pjrt` cargo feature and requires a locally-vendored
//!   `xla` binding (see Cargo.toml).
//! * [`util`], [`linalg`], [`tensor`] — substrates (error type, JSON,
//!   RNG, CLI, thread pool, bench harness, dense linear algebra,
//!   tensors) built in-tree because the build is fully offline: the
//!   default feature set has **zero** external dependencies.
//!
//! The workspace root is the repository root: `cargo build --release &&
//! cargo test -q` from there is the whole verification story, and
//! `cargo bench --bench perf_kernels` reports the hot-path numbers
//! (including the serial-vs-pooled ExactOBS speedup with a bit-identity
//! assertion). Golden conformance fixtures pin the native kernels to the
//! Python oracle layer (`rust/tests/kernel_conformance.rs`).
//!
//! ## Quickstart
//!
//! ```no_run
//! use obc::compress::{exact_obs, hessian::LayerHessian};
//! use obc::linalg::Mat;
//!
//! // Layer weights (d_row x d_col) and calibration inputs (d_col x N).
//! let w = Mat::randn(64, 128, 0x0bc);
//! let x = Mat::randn(128, 512, 0x5eed);
//! let h = LayerHessian::from_inputs(&x, 1e-8);
//! let res = exact_obs::prune_unstructured(&w, &h, 0.5, &Default::default());
//! println!("pruned to 50% sparsity, sq-err = {}", res.sq_err);
//! ```

pub mod util;
pub mod linalg;
pub mod tensor;
pub mod nn;
pub mod data;
pub mod compress;
pub mod db;
pub mod store;
pub mod solver;
pub mod cost;
pub mod stats;
pub mod eval;
pub mod coordinator;
pub mod runtime;
pub mod server;
