//! # OBC — Optimal Brain Compression
//!
//! A production-grade reproduction of *"Optimal Brain Compression: A
//! Framework for Accurate Post-Training Quantization and Pruning"*
//! (Frantar & Alistarh, NeurIPS 2022).
//!
//! The crate implements the full OBC system:
//!
//! * [`compress`] — the paper's contribution: **ExactOBS** (Algorithm 1 +
//!   the global step Algorithm 2, N:M and block-sparsity variants) and
//!   **OBQ** (Algorithm 3 + outlier heuristic), plus every baseline the
//!   paper compares against (GMP, L-OBS, AdaPrune, global AdaPrune,
//!   AdaQuant, BitSplit, AdaRound-style).
//! * [`nn`] / [`data`] — a self-contained inference engine and synthetic
//!   dataset substrate standing in for the paper's ImageNet/COCO/SQuAD
//!   models (see DESIGN.md §2 for the substitution argument).
//! * [`db`] + [`solver`] + [`cost`] — the non-uniform compression pipeline:
//!   model database, SPDY-style DP solver, FLOP/BOP/CPU-latency models.
//! * [`stats`] — batch-norm reset and mean/variance correction (Eq. 9).
//! * [`coordinator`] — the L3 orchestration layer: job scheduling across a
//!   thread pool, experiment pipelines, metrics.
//! * [`runtime`] — PJRT bridge: loads AOT-compiled HLO artifacts produced
//!   by the build-time JAX/Pallas layer and executes them from Rust, with
//!   native fallbacks for shapes outside the artifact set.
//! * [`util`], [`linalg`], [`tensor`] — substrates (JSON, RNG, CLI,
//!   thread pool, bench harness, dense linear algebra, tensors) built
//!   in-tree because the build is fully offline.
//!
//! ## Quickstart
//!
//! ```no_run
//! use obc::compress::{exact_obs, hessian::LayerHessian};
//! use obc::linalg::Mat;
//!
//! // Layer weights (d_row x d_col) and calibration inputs (d_col x N).
//! let w = Mat::randn(64, 128, 0x0bc);
//! let x = Mat::randn(128, 512, 0x5eed);
//! let h = LayerHessian::from_inputs(&x, 1e-8);
//! let res = exact_obs::prune_unstructured(&w, &h, 0.5, &Default::default());
//! println!("pruned to 50% sparsity, sq-err = {}", res.sq_err);
//! ```

pub mod util;
pub mod linalg;
pub mod tensor;
pub mod nn;
pub mod data;
pub mod compress;
pub mod db;
pub mod solver;
pub mod cost;
pub mod stats;
pub mod eval;
pub mod coordinator;
pub mod runtime;
