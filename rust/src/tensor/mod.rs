//! Minimal dense f32 N-d tensor used by the inference engine.
//!
//! Deliberately simple: contiguous row-major storage, shape-checked
//! constructors, and the handful of views the `nn` layers need (im2col is
//! implemented in `nn::conv`). Heavy math goes through `linalg::Mat` (f64)
//! or flat-slice loops.

use crate::util::rng::Pcg;

/// Contiguous row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn randn(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Pcg::new(seed);
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: (0..n).map(|_| rng.normal_f32()).collect() }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Reshape (must preserve element count).
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.numel(),
            "reshape {:?} -> {shape:?}",
            self.shape
        );
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Flat index of a multi-index.
    pub fn flat(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let strides = self.strides();
        idx.iter().zip(&strides).map(|(i, s)| i * s).sum()
    }

    #[inline]
    pub fn at4(&self, a: usize, b: usize, c: usize, d: usize) -> f32 {
        let (s1, s2, s3) = (self.shape[1], self.shape[2], self.shape[3]);
        self.data[((a * s1 + b) * s2 + c) * s3 + d]
    }

    #[inline]
    pub fn at3(&self, a: usize, b: usize, c: usize) -> f32 {
        let (s1, s2) = (self.shape[1], self.shape[2]);
        self.data[(a * s1 + b) * s2 + c]
    }

    #[inline]
    pub fn at2(&self, a: usize, b: usize) -> f32 {
        self.data[a * self.shape[1] + b]
    }

    /// Slice of the leading dimension: returns tensor with shape[1..].
    pub fn index0(&self, i: usize) -> Tensor {
        let inner: usize = self.shape[1..].iter().product();
        Tensor {
            shape: self.shape[1..].to_vec(),
            data: self.data[i * inner..(i + 1) * inner].to_vec(),
        }
    }

    /// Stack tensors of identical shape along a new leading dimension.
    pub fn stack(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let inner = &parts[0].shape;
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(inner);
        let mut data = Vec::with_capacity(parts.len() * parts[0].numel());
        for p in parts {
            assert_eq!(&p.shape, inner, "stack shape mismatch");
            data.extend_from_slice(&p.data);
        }
        Tensor { shape, data }
    }

    /// Element-wise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&v| f(v)).collect() }
    }

    /// Sum of squared differences against another tensor.
    pub fn sq_err(&self, other: &Tensor) -> f64 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum()
    }

    /// argmax over the last dimension, returning indices (flattened batch).
    pub fn argmax_last(&self) -> Vec<usize> {
        let last = *self.shape.last().expect("argmax on 0-d tensor");
        self.data
            .chunks_exact(last)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_strides() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.numel(), 24);
        assert_eq!(t.strides(), vec![12, 4, 1]);
        assert_eq!(t.flat(&[1, 2, 3]), 23);
    }

    #[test]
    fn index_helpers() {
        let t = Tensor::from_vec(&[2, 2, 2], (0..8).map(|i| i as f32).collect());
        assert_eq!(t.at3(1, 0, 1), 5.0);
        let s = t.index0(1);
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn stack_roundtrip() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![3.0, 4.0]);
        let s = Tensor::stack(&[a.clone(), b]);
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.index0(0), a);
    }

    #[test]
    fn argmax_last_rows() {
        let t = Tensor::from_vec(&[2, 3], vec![0.1, 0.9, 0.5, 2.0, -1.0, 0.0]);
        assert_eq!(t.argmax_last(), vec![1, 0]);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn from_vec_checks_len() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn sq_err_basic() {
        let a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![1.0, 0.0, 3.0]);
        assert_eq!(a.sq_err(&b), 4.0);
    }
}
