//! Statistics correction orchestration (paper §6 "Experimental Setup" +
//! Appendix A.4).
//!
//! * ResNets: **batchnorm reset** — recompute BN running statistics from
//!   calibration batches after stitching.
//! * YOLO/BERT stand-ins: **mean/variance correction** (Eq. 9) — one
//!   batch, dense reference stats recorded first, corrections applied
//!   in-flight and merged into the affine parameters.

use crate::nn::models::{batch_slice, task_of, ModelBundle};
use crate::nn::CompressibleModel;

/// How a model family recovers statistics after compression.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Correction {
    None,
    BnReset,
    MeanVar,
}

/// Paper defaults per task.
pub fn default_correction(model: &str) -> Correction {
    match task_of(model) {
        "image" => Correction::BnReset,
        "seq" | "det" => Correction::MeanVar,
        _ => Correction::None,
    }
}

/// Apply the chosen correction to a stitched model. `dense` is the
/// uncompressed model (reference statistics for MeanVar).
pub fn apply_with_dense(
    kind: Correction,
    model: &mut Box<dyn CompressibleModel>,
    dense: &dyn CompressibleModel,
    bundle: &ModelBundle,
) {
    match kind {
        Correction::None => {}
        Correction::BnReset => {
            // Paper: "batchnorm statistics are reset using 100 batches of
            // 128 samples" — our calibration split holds 1024 samples, so
            // 8 batches of 128 cover it exactly.
            let (batch, n_batches) = (128usize, 8usize);
            let n = bundle.calib_x.shape[0];
            let batches: Vec<_> = (0..n_batches)
                .filter_map(|i| {
                    let lo = i * batch;
                    if lo >= n {
                        return None;
                    }
                    Some(batch_slice(&bundle.calib_x, lo, (lo + batch).min(n)))
                })
                .collect();
            model.reset_bn_stats(&batches);
        }
        Correction::MeanVar => {
            // "a single batch of samples of size 128 (for YOLO) and 512
            // (for BERT)".
            let batch = if task_of(dense.name()) == "seq" { 512 } else { 128 };
            let n = bundle.calib_x.shape[0].min(batch);
            let xb = batch_slice(&bundle.calib_x, 0, n);
            let dense_stats = dense.activation_stats(&xb);
            model.correct_stats(&xb, &dense_stats);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        assert_eq!(default_correction("rneta"), Correction::BnReset);
        assert_eq!(default_correction("bert6"), Correction::MeanVar);
        assert_eq!(default_correction("tinydet"), Correction::MeanVar);
    }
}
