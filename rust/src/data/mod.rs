//! Calibration-data utilities: batching and the cheap augmentations the
//! paper applies to the calibration set (horizontal flips + random
//! crops-with-padding, "very cheap to include for our method" since they
//! only enter the Hessian accumulation once).

use crate::tensor::Tensor;
use crate::util::rng::Pcg;

/// Horizontal flip of an NCHW image batch.
pub fn hflip(x: &Tensor) -> Tensor {
    let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut out = x.clone();
    for bi in 0..b {
        for ci in 0..c {
            for y in 0..h {
                let base = ((bi * c + ci) * h + y) * w;
                for xx in 0..w / 2 {
                    out.data.swap(base + xx, base + w - 1 - xx);
                }
            }
        }
    }
    out
}

/// Random crop with `pad` pixels of zero padding (standard augmentation),
/// same output size. One shared offset per image.
pub fn random_crop(x: &Tensor, pad: usize, rng: &mut Pcg) -> Tensor {
    let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut out = Tensor::zeros(&x.shape);
    for bi in 0..b {
        let dy = rng.below(2 * pad + 1) as isize - pad as isize;
        let dx = rng.below(2 * pad + 1) as isize - pad as isize;
        for ci in 0..c {
            for y in 0..h {
                let sy = y as isize + dy;
                if sy < 0 || sy >= h as isize {
                    continue;
                }
                for xx in 0..w {
                    let sx = xx as isize + dx;
                    if sx < 0 || sx >= w as isize {
                        continue;
                    }
                    out.data[((bi * c + ci) * h + y) * w + xx] =
                        x.at4(bi, ci, sy as usize, sx as usize);
                }
            }
        }
    }
    out
}

/// Generate `factor`× augmented copies of an image batch (flip + crop),
/// deterministic by seed. Copy 0 is the identity.
pub fn augment(x: &Tensor, factor: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Pcg::new(seed);
    let mut out = Vec::with_capacity(factor);
    out.push(x.clone());
    for i in 1..factor {
        let mut v = if i % 2 == 1 { hflip(x) } else { x.clone() };
        v = random_crop(&v, 2, &mut rng);
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hflip_involution() {
        let x = Tensor::randn(&[2, 3, 8, 8], 1);
        assert_eq!(hflip(&hflip(&x)), x);
    }

    #[test]
    fn crop_preserves_shape() {
        let x = Tensor::randn(&[2, 3, 8, 8], 2);
        let mut rng = Pcg::new(3);
        let y = random_crop(&x, 2, &mut rng);
        assert_eq!(y.shape, x.shape);
    }

    #[test]
    fn augment_first_is_identity() {
        let x = Tensor::randn(&[1, 3, 8, 8], 4);
        let v = augment(&x, 4, 5);
        assert_eq!(v.len(), 4);
        assert_eq!(v[0], x);
        assert_ne!(v[1], x);
    }

    #[test]
    fn augment_deterministic() {
        let x = Tensor::randn(&[1, 3, 8, 8], 6);
        let a = augment(&x, 3, 7);
        let b = augment(&x, 3, 7);
        assert_eq!(a[2], b[2]);
    }
}
