//! Packed-f32 storage with f64-accumulating kernels — the mixed tier.
//!
//! The rank-B flush, SYRK band accumulation and trace-db gathers are
//! memory-bound at large d: ~2 flops per 8 loaded bytes in f64. Storing
//! the *streamed* operand as f32 halves the bytes per element while every
//! reduction still runs in f64 (each f32 load widens once into an f64
//! accumulator chain), so the error per dot is bounded by the storage
//! rounding of the inputs (≈ 2⁻²⁴ relative per element), not by
//! accumulation drift. This is the paper's own operating point — the
//! reference GPU implementation computes in f32 — but kept strictly
//! opt-in behind [`crate::util::precision::Precision::Mixed`]: the f64
//! kernels remain the bit-pinned oracles and every mixed kernel is
//! tolerance-pinned against them.
//!
//! The inner loops here unroll **8 outputs wide** where the f64 kernels
//! unroll 4: with half the bytes per lane the same vector width covers
//! twice the columns, so the unroll factor doubles to keep the load
//! ports saturated. As in the f64 kernels, the unroll is across
//! *outputs*, never within a reduction — each (i,j) dot is one
//! sequential t-sweep, so the mixed SYRK is bitwise reproducible for any
//! unroll/tile/thread configuration (pinned by tests), merely not
//! bit-equal to the f64 oracle.

use super::mat::{band_bounds, Mat};

/// Row-major dense matrix of f32 — the storage half of the mixed tier.
/// Constructed by narrowing an f64 [`Mat`] once per layer/batch; all
/// arithmetic on it accumulates in f64.
#[derive(Debug, Clone, PartialEq)]
pub struct FMat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl FMat {
    pub fn zeros(rows: usize, cols: usize) -> FMat {
        FMat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Narrow an f64 matrix to f32 storage (lossy — see [`Mat::to_f32`]).
    pub fn from_mat(m: &Mat) -> FMat {
        FMat { rows: m.rows, cols: m.cols, data: m.to_f32() }
    }

    /// Build directly from an f32 slice.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> FMat {
        assert_eq!(data.len(), rows * cols);
        FMat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Widen back to f64 (exact — every f32 is representable).
    pub fn to_mat(&self) -> Mat {
        Mat::from_f32(self.rows, self.cols, &self.data)
    }

    /// Mixed-tier `out += alpha · self·selfᵀ`: the f32-storage mirror of
    /// [`Mat::xxt_acc_threads`] — same band split, same serial cutoff,
    /// same upper-triangle tile merge, but each band runs
    /// [`syrk_upper_rows_mixed`] (f32 loads, f64 accumulators, 8-wide).
    /// Deterministic for any thread count: every (i,j) dot is one
    /// sequential f64 reduction over widened f32 loads computed by
    /// exactly one band.
    pub fn xxt_acc_threads_mixed(
        &self,
        out: &mut Mat,
        alpha: f64,
        threads: usize,
        tile: &mut Vec<f64>,
    ) {
        let (m, k) = (self.rows, self.cols);
        assert_eq!(out.rows, m, "xxt_acc_mixed: out rows");
        assert_eq!(out.cols, m, "xxt_acc_mixed: out cols");
        if tile.len() < m * m {
            tile.resize(m * m, 0.0);
        }
        // Same flop heuristic as the f64 kernel: below ~2^21 madds the
        // spawn overhead dominates.
        let nt = if m * m * k / 2 < (1 << 21) { 1 } else { threads.clamp(1, m.max(1)) };
        if nt <= 1 {
            syrk_upper_rows_mixed(&self.data, m, k, 0, m, &mut tile[..m * m]);
        } else {
            let bounds = band_bounds(m, nt);
            let mut bands: Vec<(usize, usize, &mut [f64])> =
                Vec::with_capacity(bounds.len() - 1);
            let mut rest: &mut [f64] = &mut tile[..m * m];
            for wnd in bounds.windows(2) {
                let (r0, r1) = (wnd[0], wnd[1]);
                let (band, tail) = rest.split_at_mut((r1 - r0) * m);
                rest = tail;
                bands.push((r0, r1, band));
            }
            std::thread::scope(|scope| {
                for (r0, r1, band) in bands {
                    let data = &self.data;
                    scope.spawn(move || {
                        syrk_upper_rows_mixed(data, m, k, r0, r1, band);
                    });
                }
            });
        }
        for i in 0..m {
            let base = i * m;
            out.data[base + i] += alpha * tile[base + i];
            for j in i + 1..m {
                let s = tile[base + j];
                out.data[base + j] += alpha * s;
                out.data[j * m + i] += alpha * s;
            }
        }
    }
}

/// Mixed-tier upper-triangle SYRK over rows `r0..r1`: f32 row loads,
/// f64 accumulators, written at `out[(i−r0)·m + j]` for j ≥ i. Mirror of
/// `mat::syrk_upper_rows` with the output unroll widened from 4 to 8
/// (f32 lanes are half-width, so 8 outputs keep the same vector
/// footprint) and the same 64-column cache tiling. Each (i,j) entry is
/// one sequential f64 dot over widened f32 elements, so the result is
/// bitwise identical to the scalar mixed reference for any tile/unroll
/// placement — the unroll is across outputs only.
pub(crate) fn syrk_upper_rows_mixed(
    data: &[f32],
    m: usize,
    k: usize,
    r0: usize,
    r1: usize,
    out: &mut [f64],
) {
    const TILE: usize = 64;
    let mut jt = r0;
    while jt < m {
        let jt1 = (jt + TILE).min(m);
        for i in r0..r1.min(jt1) {
            let ri = &data[i * k..(i + 1) * k];
            let orow = &mut out[(i - r0) * m..(i - r0 + 1) * m];
            let mut j = jt.max(i);
            while j + 8 <= jt1 {
                let rj0 = &data[j * k..(j + 1) * k];
                let rj1 = &data[(j + 1) * k..(j + 2) * k];
                let rj2 = &data[(j + 2) * k..(j + 3) * k];
                let rj3 = &data[(j + 3) * k..(j + 4) * k];
                let rj4 = &data[(j + 4) * k..(j + 5) * k];
                let rj5 = &data[(j + 5) * k..(j + 6) * k];
                let rj6 = &data[(j + 6) * k..(j + 7) * k];
                let rj7 = &data[(j + 7) * k..(j + 8) * k];
                let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0, 0.0, 0.0);
                let (mut s4, mut s5, mut s6, mut s7) = (0.0f64, 0.0, 0.0, 0.0);
                for t in 0..k {
                    let a = ri[t] as f64;
                    s0 += a * rj0[t] as f64;
                    s1 += a * rj1[t] as f64;
                    s2 += a * rj2[t] as f64;
                    s3 += a * rj3[t] as f64;
                    s4 += a * rj4[t] as f64;
                    s5 += a * rj5[t] as f64;
                    s6 += a * rj6[t] as f64;
                    s7 += a * rj7[t] as f64;
                }
                orow[j] = s0;
                orow[j + 1] = s1;
                orow[j + 2] = s2;
                orow[j + 3] = s3;
                orow[j + 4] = s4;
                orow[j + 5] = s5;
                orow[j + 6] = s6;
                orow[j + 7] = s7;
                j += 8;
            }
            while j < jt1 {
                let rj = &data[j * k..(j + 1) * k];
                let mut s = 0.0f64;
                for t in 0..k {
                    s += ri[t] as f64 * rj[t] as f64;
                }
                orow[j] = s;
                j += 1;
            }
        }
        jt = jt1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The 8-wide unroll and the 64-column tiling must not change a
    /// single bit vs a scalar f32-load/f64-accumulate dot.
    #[test]
    fn mixed_syrk_bit_identical_to_scalar_mixed_dot() {
        // 64 + 11 crosses the tile seam; odd k exercises no special
        // path (reduction is sequential) but keeps sizes honest.
        let x = FMat::from_mat(&Mat::randn(75, 37, 91));
        let (m, k) = (x.rows, x.cols);
        let mut out = vec![f64::NAN; m * m];
        syrk_upper_rows_mixed(&x.data, m, k, 0, m, &mut out);
        for i in 0..m {
            for j in i..m {
                let mut s = 0.0f64;
                for t in 0..k {
                    s += x.at(i, t) as f64 * x.at(j, t) as f64;
                }
                assert_eq!(out[i * m + j].to_bits(), s.to_bits(), "mixed syrk ({i},{j})");
            }
        }
    }

    /// Banded multi-thread mixed SYRK is deterministic for any thread
    /// count (same bits as the serial mixed run) and reuses the tile.
    #[test]
    fn mixed_xxt_acc_threads_deterministic_any_thread_count() {
        let m = 80;
        let x = FMat::from_mat(&Mat::randn(m, 1100, 19));
        let start = Mat::randn(m, m, 20);
        let mut tile = Vec::new();
        let mut serial = start.clone();
        x.xxt_acc_threads_mixed(&mut serial, 2.0, 1, &mut tile);
        for threads in [2usize, 5] {
            let mut out = start.clone();
            x.xxt_acc_threads_mixed(&mut out, 2.0, threads, &mut tile);
            assert_eq!(out.data, serial.data, "threads={threads}");
        }
        let cap = tile.capacity();
        let mut out = start.clone();
        x.xxt_acc_threads_mixed(&mut out, 2.0, 3, &mut tile);
        assert_eq!(tile.capacity(), cap, "tile must be reused, not regrown");
    }

    /// Tolerance pin against the f64 oracle: per-entry relative error of
    /// the mixed SYRK vs `Mat::xxt_acc_threads` bounded by the f32
    /// storage rounding (≈ k·2⁻²³ worst case; 1e-4 is generous at
    /// k ≈ 1000 with standard-normal data).
    #[test]
    fn mixed_syrk_within_tolerance_of_f64_oracle() {
        let xf = Mat::randn(40, 600, 33);
        let x = FMat::from_mat(&xf);
        let mut exact = Mat::zeros(40, 40);
        let mut mixed = Mat::zeros(40, 40);
        let mut tile = Vec::new();
        xf.xxt_acc_threads(&mut exact, 1.0, 1, &mut tile);
        let mut tile2 = Vec::new();
        x.xxt_acc_threads_mixed(&mut mixed, 1.0, 1, &mut tile2);
        for (i, (&a, &b)) in exact.data.iter().zip(&mixed.data).enumerate() {
            let rel = (a - b).abs() / (1.0 + a.abs());
            assert!(rel < 1e-4, "entry {i}: f64 {a:e} vs mixed {b:e} (rel {rel:e})");
        }
    }

    #[test]
    fn from_mat_round_trips_f32_data() {
        let m = Mat::randn(5, 7, 3);
        let f = FMat::from_mat(&m);
        assert_eq!(FMat::from_mat(&f.to_mat()).data, f.data);
    }
}
