//! Row-major dense f64 matrix with the operations the OBS/OBQ math needs.

use crate::util::rng::Pcg;

/// Row-major dense matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// From an f32 slice (weights coming out of the inference engine).
    ///
    /// Widening `f32 → f64` is **exact** for every f32 value, including
    /// subnormals and signed zeros; NaN stays NaN (payload widened) and
    /// ±∞ stay ±∞. Therefore `Mat::from_f32(..).to_f32()` reproduces the
    /// input bit pattern for all non-NaN values (NaN compares unequal but
    /// remains NaN).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.iter().map(|&v| v as f64).collect() }
    }

    /// Narrow to f32 — **lossy** in general: values round to the nearest
    /// f32 (ties-to-even), magnitudes above `f32::MAX` overflow to ±∞,
    /// and magnitudes below the subnormal range flush toward ±0. NaN maps
    /// to NaN and ±∞ to ±∞. Integers with |v| ≤ 2²⁴ and all f64 values
    /// that originated as f32 narrow exactly, so
    /// `to_f32 ∘ from_f32 = id` on such data (tested by
    /// `f32_round_trip_semantics`).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    /// Standard-normal random matrix (deterministic by seed).
    pub fn randn(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Pcg::new(seed);
        let data = (0..rows * cols).map(|_| rng.normal()).collect();
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn col(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// `self * other` — cache-blocked ikj matmul, dense unconditional
    /// inner kernel. For matrices with many exact zeros in `self` (e.g.
    /// post-pruning weights) use [`Mat::matmul_masked`], which skips
    /// whole B-row streams per zero: the zero test costs a branch per
    /// element here, which penalizes the dense common case (measured by
    /// the `matmul_dense_*` cases in `benches/perf_kernels.rs`).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        const BK: usize = 64;
        for kb in (0..k).step_by(BK) {
            let kend = (kb + BK).min(k);
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let a = arow[kk];
                    let brow = &other.data[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        orow[j] += a * brow[j];
                    }
                }
            }
        }
        out
    }

    /// [`Mat::matmul`] with an explicit zero mask on `self`: every exact
    /// zero skips its whole length-n B-row accumulation. The win scales
    /// with the LHS sparsity (2–10× on 50–90% pruned weights); on dense
    /// inputs the per-element branch makes it strictly slower than
    /// `matmul`, which is why the two are separate kernels.
    pub fn matmul_masked(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        const BK: usize = 64;
        for kb in (0..k).step_by(BK) {
            let kend = (kb + BK).min(k);
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let a = arow[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        orow[j] += a * brow[j];
                    }
                }
            }
        }
        out
    }

    /// `self * selfᵀ` exploiting symmetry (used for Hessian X·Xᵀ where
    /// self = X of shape d_col × N — call on X to get d_col × d_col).
    pub fn xxt(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.rows);
        self.xxt_into(&mut out);
        out
    }

    /// [`Mat::xxt`] into caller-provided storage (no allocation). Each
    /// (i,j) entry is one full-length dot of rows i and j — the same
    /// reduction order as `xxt` has always used, so results are
    /// bit-identical to it.
    pub fn xxt_into(&self, out: &mut Mat) {
        assert_eq!(out.rows, self.rows, "xxt_into: out rows");
        assert_eq!(out.cols, self.rows, "xxt_into: out cols");
        let (m, k) = (self.rows, self.cols);
        syrk_upper_rows(&self.data, m, k, 0, m, &mut out.data);
        for i in 0..m {
            for j in i + 1..m {
                out.data[j * m + i] = out.data[i * m + j];
            }
        }
    }

    /// `out += alpha · self·selfᵀ` — the Hessian-accumulation SYRK,
    /// fanned over `threads` scoped worker threads in row bands of
    /// ~equal upper-triangle area. `tile` is caller-owned upper-triangle
    /// workspace (grown to m×m once, then reused across batches, so
    /// steady-state accumulation performs no allocation).
    ///
    /// Determinism: every (i,j) dot is computed by exactly one band with
    /// the same reduction order as [`Mat::xxt`], and the merge applies
    /// `out[i][j] += alpha·s` to both mirror positions — bit-identical
    /// to the historical `xxt` + `axpy(alpha, ·)` for any thread count.
    ///
    /// Spawns plain scoped threads rather than borrowing the global job
    /// pool, so it is safe to call from inside pool jobs (no
    /// pool-in-pool deadlock) and needs no `Arc` clone of `self`.
    pub fn xxt_acc_threads(&self, out: &mut Mat, alpha: f64, threads: usize, tile: &mut Vec<f64>) {
        let (m, k) = (self.rows, self.cols);
        assert_eq!(out.rows, m, "xxt_acc: out rows");
        assert_eq!(out.cols, m, "xxt_acc: out cols");
        if tile.len() < m * m {
            tile.resize(m * m, 0.0);
        }
        // Flop heuristic: below ~2^21 madds the spawn overhead dominates.
        let nt = if m * m * k / 2 < (1 << 21) { 1 } else { threads.clamp(1, m.max(1)) };
        if nt <= 1 {
            syrk_upper_rows(&self.data, m, k, 0, m, &mut tile[..m * m]);
        } else {
            // Pre-split the tile into disjoint &mut bands, then hand one
            // band to each scoped thread (borrows end before the merge).
            let bounds = band_bounds(m, nt);
            let mut bands: Vec<(usize, usize, &mut [f64])> =
                Vec::with_capacity(bounds.len() - 1);
            let mut rest: &mut [f64] = &mut tile[..m * m];
            for wnd in bounds.windows(2) {
                let (r0, r1) = (wnd[0], wnd[1]);
                let (band, tail) = rest.split_at_mut((r1 - r0) * m);
                rest = tail;
                bands.push((r0, r1, band));
            }
            std::thread::scope(|scope| {
                for (r0, r1, band) in bands {
                    let data = &self.data;
                    scope.spawn(move || {
                        // Band rows write tile offsets relative to r0.
                        syrk_upper_rows(data, m, k, r0, r1, band);
                    });
                }
            });
        }
        // Merge the upper-triangle tile into both mirror positions.
        for i in 0..m {
            let base = i * m;
            out.data[base + i] += alpha * tile[base + i];
            for j in i + 1..m {
                let s = tile[base + j];
                out.data[base + j] += alpha * s;
                out.data[j * m + i] += alpha * s;
            }
        }
    }

    /// Matrix–vector product. Rows are processed four at a time with
    /// independent accumulators (one per output) so the loads of `v`
    /// are shared and the four dots vectorize; each row's reduction
    /// still runs in its own left-to-right order, so every output is
    /// bit-identical to the one-row-at-a-time version.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        let (m, k) = (self.rows, self.cols);
        let mut out = vec![0.0; m];
        let mut r = 0usize;
        while r + 4 <= m {
            let r0 = &self.data[r * k..(r + 1) * k];
            let r1 = &self.data[(r + 1) * k..(r + 2) * k];
            let r2 = &self.data[(r + 2) * k..(r + 3) * k];
            let r3 = &self.data[(r + 3) * k..(r + 4) * k];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
            for t in 0..k {
                let x = v[t];
                s0 += r0[t] * x;
                s1 += r1[t] * x;
                s2 += r2[t] * x;
                s3 += r3[t] * x;
            }
            out[r] = s0;
            out[r + 1] = s1;
            out[r + 2] = s2;
            out[r + 3] = s3;
            r += 4;
        }
        for i in r..m {
            let row = self.row(i);
            out[i] = row.iter().zip(v).map(|(a, b)| a * b).sum();
        }
        out
    }

    /// Frobenius norm of (self - other).
    pub fn dist(&self, other: &Mat) -> f64 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// In-place scaled add: self += alpha * other.
    pub fn axpy(&mut self, alpha: f64, other: &Mat) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Add `v` to the diagonal (dampening).
    pub fn add_diag(&mut self, v: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += v;
        }
    }

    pub fn diag_mean(&self) -> f64 {
        let n = self.rows.min(self.cols);
        if n == 0 {
            return 0.0;
        }
        (0..n).map(|i| self.data[i * self.cols + i]).sum::<f64>() / n as f64
    }

    /// Extract the submatrix with the given row and column index sets.
    pub fn submatrix(&self, row_idx: &[usize], col_idx: &[usize]) -> Mat {
        let mut m = Mat::zeros(row_idx.len(), col_idx.len());
        for (ri, &r) in row_idx.iter().enumerate() {
            for (ci, &c) in col_idx.iter().enumerate() {
                m.data[ri * col_idx.len() + ci] = self.at(r, c);
            }
        }
        m
    }
}

/// Upper-triangle SYRK over rows `r0..r1`: `s(i,j) = rowᵢ·rowⱼ` for
/// j ≥ i, written at `out[(i−r0)·m + j]` (pass the full m×m buffer with
/// `r0 = 0`, or a band slice starting at row r0). One full-length dot
/// per entry — the reduction order `Mat::xxt` has always used.
///
/// Four j-columns are produced per pass with independent accumulators
/// (the loads of rowᵢ amortize 4×, and the four dots map onto f64x4
/// lanes); each (i,j) reduction is still one sequential sweep over t, so
/// every entry is bit-identical to the one-dot-at-a-time version — the
/// unroll is across *outputs*, never within a reduction.
///
/// The j dimension is additionally walked in 64-column **cache tiles**,
/// with the band's rows iterated *inside* each tile: one tile's 64 rhs
/// rows (64·k doubles) stay resident in L2 while every rowᵢ of the band
/// streams past them, instead of the whole m·k matrix being re-fetched
/// per i. Tiling only reorders which (i,j) *outputs* are produced when —
/// every output is still one whole sequential dot, written once — so the
/// result is bitwise identical to the untiled walk (pinned by
/// `xxt_acc_threads_bit_identical_any_thread_count` with m > 64).
const SYRK_COL_TILE: usize = 64;

pub(crate) fn syrk_upper_rows(data: &[f64], m: usize, k: usize, r0: usize, r1: usize, out: &mut [f64]) {
    let mut jt = r0;
    while jt < m {
        let jt1 = (jt + SYRK_COL_TILE).min(m);
        // Rows above the tile's diagonal block take the whole tile;
        // rows inside it start at their own diagonal (j ≥ i).
        for i in r0..r1.min(jt1) {
            let ri = &data[i * k..(i + 1) * k];
            let orow = &mut out[(i - r0) * m..(i - r0 + 1) * m];
            let mut j = jt.max(i);
            while j + 4 <= jt1 {
                let rj0 = &data[j * k..(j + 1) * k];
                let rj1 = &data[(j + 1) * k..(j + 2) * k];
                let rj2 = &data[(j + 2) * k..(j + 3) * k];
                let rj3 = &data[(j + 3) * k..(j + 4) * k];
                let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
                for t in 0..k {
                    let a = ri[t];
                    s0 += a * rj0[t];
                    s1 += a * rj1[t];
                    s2 += a * rj2[t];
                    s3 += a * rj3[t];
                }
                orow[j] = s0;
                orow[j + 1] = s1;
                orow[j + 2] = s2;
                orow[j + 3] = s3;
                j += 4;
            }
            while j < jt1 {
                let rj = &data[j * k..(j + 1) * k];
                let mut s = 0.0;
                for t in 0..k {
                    s += ri[t] * rj[t];
                }
                orow[j] = s;
                j += 1;
            }
        }
        jt = jt1;
    }
}

/// Partition rows `0..m` into at most `nt` contiguous bands of ~equal
/// upper-triangle area (row i contributes m−i dot products).
pub(crate) fn band_bounds(m: usize, nt: usize) -> Vec<usize> {
    let total = (m as u64) * (m as u64 + 1) / 2;
    let target = total / nt as u64 + 1;
    let mut bounds = vec![0usize];
    let mut acc = 0u64;
    for i in 0..m {
        acc += (m - i) as u64;
        if acc >= target && i + 1 < m {
            bounds.push(i + 1);
            acc = 0;
        }
    }
    bounds.push(m);
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::randn(5, 7, 1);
        let i7 = Mat::eye(7);
        let p = a.matmul(&i7);
        assert!(a.dist(&p) < 1e-12);
    }

    #[test]
    fn matmul_matches_naive() {
        let a = Mat::randn(9, 13, 2);
        let b = Mat::randn(13, 6, 3);
        let c = a.matmul(&b);
        for i in 0..9 {
            for j in 0..6 {
                let mut s = 0.0;
                for k in 0..13 {
                    s += a.at(i, k) * b.at(k, j);
                }
                assert!((c.at(i, j) - s).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn xxt_matches_matmul() {
        let x = Mat::randn(8, 20, 4);
        let h1 = x.xxt();
        let h2 = x.matmul(&x.transpose());
        assert!(h1.dist(&h2) < 1e-10);
    }

    /// The masked kernel must agree with the dense kernel bit-for-bit —
    /// skipping `a == 0` rows only elides ±0 contributions, which never
    /// change an accumulator that starts at +0.
    #[test]
    fn matmul_masked_matches_dense_bitwise() {
        let a = Mat::randn(7, 33, 7);
        let b = Mat::randn(33, 9, 8);
        assert_eq!(a.matmul(&b).data, a.matmul_masked(&b).data);
        // 2/3-sparse LHS (the masked kernel's target shape).
        let mut sp = a.clone();
        for (i, v) in sp.data.iter_mut().enumerate() {
            if i % 3 != 0 {
                *v = 0.0;
            }
        }
        assert_eq!(sp.matmul(&b).data, sp.matmul_masked(&b).data);
    }

    #[test]
    fn xxt_into_matches_xxt() {
        let x = Mat::randn(12, 30, 14);
        let mut out = Mat::randn(12, 12, 15); // dirty output buffer
        x.xxt_into(&mut out);
        assert_eq!(out.data, x.xxt().data);
    }

    /// Banded multi-thread SYRK accumulation must be bit-identical to
    /// the historical `xxt` + `axpy` for any thread count, and reuse the
    /// caller's tile without reallocating.
    #[test]
    fn xxt_acc_threads_bit_identical_any_thread_count() {
        // Large enough to clear the serial cutoff (m²k/2 ≥ 2²¹) AND to
        // cross the 64-column SYRK cache tile (m > SYRK_COL_TILE).
        let m = SYRK_COL_TILE + 16;
        let x = Mat::randn(m, 1100, 9);
        let mut legacy = Mat::randn(m, m, 10); // nonzero accumulator
        let start = legacy.clone();
        legacy.axpy(2.0, &x.xxt());
        let mut tile = Vec::new();
        for threads in [1usize, 2, 5] {
            let mut out = start.clone();
            x.xxt_acc_threads(&mut out, 2.0, threads, &mut tile);
            assert_eq!(out.data, legacy.data, "threads={threads}");
        }
        let cap = tile.capacity();
        let mut out = start.clone();
        x.xxt_acc_threads(&mut out, 2.0, 3, &mut tile);
        assert_eq!(tile.capacity(), cap, "tile must be reused, not regrown");
    }

    #[test]
    fn band_bounds_cover_and_balance() {
        for (m, nt) in [(1usize, 1usize), (7, 3), (64, 5), (288, 8)] {
            let b = band_bounds(m, nt);
            assert_eq!(*b.first().unwrap(), 0);
            assert_eq!(*b.last().unwrap(), m);
            assert!(b.windows(2).all(|w| w[0] < w[1]), "{b:?}");
            assert!(b.len() - 1 <= nt, "{b:?} has more than {nt} bands");
        }
    }

    /// The 4-wide output unrolls and the 64-column cache tiling must not
    /// change a single bit: each output's reduction is still one
    /// sequential t-sweep, written exactly once.
    #[test]
    fn unrolled_kernels_bit_identical_to_scalar() {
        // Odd sizes exercise the unroll tails; m = 71 crosses the
        // 64-column tile boundary (tile seam at j = 64, partial second
        // tile of 7 columns).
        let x = Mat::randn(SYRK_COL_TILE + 7, 37, 31);
        let (m, k) = (x.rows, x.cols);
        let mut out = vec![f64::NAN; m * m];
        syrk_upper_rows(&x.data, m, k, 0, m, &mut out);
        for i in 0..m {
            for j in i..m {
                let mut s = 0.0;
                for t in 0..k {
                    s += x.at(i, t) * x.at(j, t);
                }
                assert_eq!(out[i * m + j].to_bits(), s.to_bits(), "syrk ({i},{j})");
            }
        }
        let v: Vec<f64> = (0..k).map(|t| (t as f64) * 0.19 - 3.0).collect();
        let mv = x.matvec(&v);
        for i in 0..m {
            let s: f64 = x.row(i).iter().zip(&v).map(|(a, b)| a * b).sum();
            assert_eq!(mv[i].to_bits(), s.to_bits(), "matvec row {i}");
        }
    }

    /// `from_f32` widens exactly (every f32 is representable in f64);
    /// `to_f32` narrows lossily but is the exact inverse on data that
    /// originated as f32. Covers subnormals, signed zero, NaN/inf, the
    /// exactly-representable integer range boundary (2²⁴), and overflow
    /// past `f32::MAX`.
    #[test]
    fn f32_round_trip_semantics() {
        let specials: Vec<f32> = vec![
            0.0,
            -0.0,
            1.5,
            -3.25,
            f32::MIN_POSITIVE,          // smallest normal
            f32::MIN_POSITIVE / 2.0,    // subnormal
            f32::from_bits(1),          // smallest subnormal
            -f32::from_bits(1),
            f32::MAX,
            f32::MIN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            16_777_216.0, // 2^24: last exactly-representable integer
            2.0f32.powi(24) - 1.0,
        ];
        let m = Mat::from_f32(specials.len(), 1, &specials);
        // Widening is exact: same bit pattern back for non-NaN, NaN→NaN.
        for (i, (orig, back)) in specials.iter().zip(m.to_f32()).enumerate() {
            if orig.is_nan() {
                assert!(back.is_nan());
                assert!(m.data[i].is_nan(), "widened NaN must stay NaN");
            } else {
                assert_eq!(orig.to_bits(), back.to_bits(), "round trip {orig:e}");
                assert_eq!(*orig as f64, m.data[i], "widening must be exact");
            }
        }
        // Narrowing is lossy: 2^24 + 1 is not representable in f32 and
        // rounds to even (2^24); beyond f32::MAX overflows to ∞; tiny
        // f64 values flush into the subnormal range or to zero.
        let lossy = Mat::from_vec(1, 4, vec![16_777_217.0, 1e300, -1e300, 1e-300]);
        let n = lossy.to_f32();
        assert_eq!(n[0], 16_777_216.0);
        assert_eq!(n[1], f32::INFINITY);
        assert_eq!(n[2], f32::NEG_INFINITY);
        assert_eq!(n[3], 0.0);
        // Integers up to 2^24 in magnitude narrow exactly.
        let ints = Mat::from_vec(1, 3, vec![-16_777_216.0, 123_456.0, 42.0]);
        assert_eq!(ints.to_f32(), vec![-16_777_216.0f32, 123_456.0, 42.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::randn(4, 6, 5);
        assert_eq!(a, a.transpose().transpose());
    }

    #[test]
    fn matvec_matches() {
        let a = Mat::randn(3, 4, 6);
        let v = vec![1.0, -2.0, 0.5, 3.0];
        let out = a.matvec(&v);
        for i in 0..3 {
            let s: f64 = (0..4).map(|j| a.at(i, j) * v[j]).sum();
            assert!((out[i] - s).abs() < 1e-12);
        }
    }

    #[test]
    fn submatrix_extracts() {
        let a = Mat::from_rows(vec![
            vec![1.0, 2.0, 3.0],
            vec![4.0, 5.0, 6.0],
            vec![7.0, 8.0, 9.0],
        ]);
        let s = a.submatrix(&[0, 2], &[1, 2]);
        assert_eq!(s.data, vec![2.0, 3.0, 8.0, 9.0]);
    }

    #[test]
    fn add_diag_and_mean() {
        let mut a = Mat::zeros(3, 3);
        a.add_diag(2.0);
        assert_eq!(a.diag_mean(), 2.0);
    }
}
