//! Row-major dense f64 matrix with the operations the OBS/OBQ math needs.

use crate::util::rng::Pcg;

/// Row-major dense matrix of f64.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// From an f32 slice (weights coming out of the inference engine).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.iter().map(|&v| v as f64).collect() }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    /// Standard-normal random matrix (deterministic by seed).
    pub fn randn(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Pcg::new(seed);
        let data = (0..rows * cols).map(|_| rng.normal()).collect();
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn col(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// `self * other` — cache-blocked ikj matmul.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        const BK: usize = 64;
        for kb in (0..k).step_by(BK) {
            let kend = (kb + BK).min(k);
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let a = arow[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        orow[j] += a * brow[j];
                    }
                }
            }
        }
        out
    }

    /// `self * selfᵀ` exploiting symmetry (used for Hessian X·Xᵀ where
    /// self = X of shape d_col × N — call on X to get d_col × d_col).
    pub fn xxt(&self) -> Mat {
        let (m, k) = (self.rows, self.cols);
        let mut out = Mat::zeros(m, m);
        for i in 0..m {
            let ri = &self.data[i * k..(i + 1) * k];
            for j in i..m {
                let rj = &self.data[j * k..(j + 1) * k];
                let mut s = 0.0;
                for t in 0..k {
                    s += ri[t] * rj[t];
                }
                out.data[i * m + j] = s;
                out.data[j * m + i] = s;
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|r| {
                let row = self.row(r);
                row.iter().zip(v).map(|(a, b)| a * b).sum()
            })
            .collect()
    }

    /// Frobenius norm of (self - other).
    pub fn dist(&self, other: &Mat) -> f64 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// In-place scaled add: self += alpha * other.
    pub fn axpy(&mut self, alpha: f64, other: &Mat) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Add `v` to the diagonal (dampening).
    pub fn add_diag(&mut self, v: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += v;
        }
    }

    pub fn diag_mean(&self) -> f64 {
        let n = self.rows.min(self.cols);
        if n == 0 {
            return 0.0;
        }
        (0..n).map(|i| self.data[i * self.cols + i]).sum::<f64>() / n as f64
    }

    /// Extract the submatrix with the given row and column index sets.
    pub fn submatrix(&self, row_idx: &[usize], col_idx: &[usize]) -> Mat {
        let mut m = Mat::zeros(row_idx.len(), col_idx.len());
        for (ri, &r) in row_idx.iter().enumerate() {
            for (ci, &c) in col_idx.iter().enumerate() {
                m.data[ri * col_idx.len() + ci] = self.at(r, c);
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::randn(5, 7, 1);
        let i7 = Mat::eye(7);
        let p = a.matmul(&i7);
        assert!(a.dist(&p) < 1e-12);
    }

    #[test]
    fn matmul_matches_naive() {
        let a = Mat::randn(9, 13, 2);
        let b = Mat::randn(13, 6, 3);
        let c = a.matmul(&b);
        for i in 0..9 {
            for j in 0..6 {
                let mut s = 0.0;
                for k in 0..13 {
                    s += a.at(i, k) * b.at(k, j);
                }
                assert!((c.at(i, j) - s).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn xxt_matches_matmul() {
        let x = Mat::randn(8, 20, 4);
        let h1 = x.xxt();
        let h2 = x.matmul(&x.transpose());
        assert!(h1.dist(&h2) < 1e-10);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::randn(4, 6, 5);
        assert_eq!(a, a.transpose().transpose());
    }

    #[test]
    fn matvec_matches() {
        let a = Mat::randn(3, 4, 6);
        let v = vec![1.0, -2.0, 0.5, 3.0];
        let out = a.matvec(&v);
        for i in 0..3 {
            let s: f64 = (0..4).map(|j| a.at(i, j) * v[j]).sum();
            assert!((out[i] - s).abs() < 1e-12);
        }
    }

    #[test]
    fn submatrix_extracts() {
        let a = Mat::from_rows(vec![
            vec![1.0, 2.0, 3.0],
            vec![4.0, 5.0, 6.0],
            vec![7.0, 8.0, 9.0],
        ]);
        let s = a.submatrix(&[0, 2], &[1, 2]);
        assert_eq!(s.data, vec![2.0, 3.0, 8.0, 9.0]);
    }

    #[test]
    fn add_diag_and_mean() {
        let mut a = Mat::zeros(3, 3);
        a.add_diag(2.0);
        assert_eq!(a.diag_mean(), 2.0);
    }
}
