//! Dense linear algebra substrate.
//!
//! All compression math (Hessians, inverses, OBS updates) runs in `f64`
//! for numerical robustness — the paper's GPU implementation uses f32 and
//! reports occasional dampening needs; f64 on CPU removes most of that
//! fragility while keeping the algorithms identical. Weights enter as f32
//! (the inference engine's dtype) and are converted per layer.
//!
//! The opt-in **mixed tier** ([`FMat`], `OBC_PRECISION=mixed`) stores the
//! streamed operand of the bandwidth-bound kernels as packed f32 while
//! every reduction still accumulates in f64 — half the memory traffic,
//! tolerance-pinned against the f64 oracles, never the default.

mod mat;
mod chol;
mod fmat;
mod inverse;

pub use chol::{
    cholesky, cholesky_append, cholesky_backward_strided, cholesky_blocked,
    cholesky_blocked_mixed, cholesky_forward_strided, cholesky_inverse, cholesky_solve,
    cholesky_solve_strided, CholFail,
};
pub use fmat::FMat;
pub use inverse::{gauss_jordan_inverse, remove_row_col, remove_row_col_into};
pub use mat::Mat;
