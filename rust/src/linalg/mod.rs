//! Dense linear algebra substrate.
//!
//! All compression math (Hessians, inverses, OBS updates) runs in `f64`
//! for numerical robustness — the paper's GPU implementation uses f32 and
//! reports occasional dampening needs; f64 on CPU removes most of that
//! fragility while keeping the algorithms identical. Weights enter as f32
//! (the inference engine's dtype) and are converted per layer.

mod mat;
mod chol;
mod inverse;

pub use chol::{
    cholesky, cholesky_append, cholesky_backward_strided, cholesky_blocked,
    cholesky_forward_strided, cholesky_inverse, cholesky_solve, cholesky_solve_strided, CholFail,
};
pub use inverse::{gauss_jordan_inverse, remove_row_col, remove_row_col_into};
pub use mat::Mat;
