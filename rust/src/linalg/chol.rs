//! Cholesky decomposition, SPD solve and SPD inverse.
//!
//! The layer Hessian H = 2XXᵀ (+ dampening) is symmetric positive
//! definite, so its inverse — the quantity every OBS formula consumes —
//! is computed via Cholesky: numerically stable and ~2× cheaper than
//! Gauss–Jordan.

use super::Mat;

/// A non-positive (or non-finite) pivot hit while factoring: `row` is
/// the 0-based row of the (sub)problem being factored at which the
/// reduced diagonal `a(i,i) − Σₖ l_ik²` stopped being positive, `diag`
/// that offending value (finite-negative for an indefinite matrix, NaN
/// when the inputs were already corrupt). Callers that factor gathered
/// submatrices map `row` back to the original index they gathered from,
/// so non-SPD diagnostics name the real culprit column.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CholFail {
    pub row: usize,
    pub diag: f64,
}

/// Lower-triangular Cholesky factor L with A = L·Lᵀ.
/// Returns Err if A is not (numerically) positive definite.
///
/// The inner reductions run over contiguous row prefixes of L (row-major
/// slices, no strided column walks), so the Θ(n³) loop streams through
/// cache lines instead of jumping a full row width per element.
pub fn cholesky(a: &Mat) -> crate::util::error::Result<Mat> {
    crate::ensure!(a.rows == a.cols, "cholesky needs a square matrix");
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        // Split so rows 0..i are readable while row i is written.
        let (done, cur) = l.data.split_at_mut(i * n);
        let rowi = &mut cur[..n];
        for j in 0..i {
            let rowj = &done[j * n..j * n + n];
            let mut s = a.at(i, j);
            for k in 0..j {
                s -= rowi[k] * rowj[k];
            }
            rowi[j] = s / rowj[j];
        }
        let mut s = a.at(i, i);
        for k in 0..i {
            s -= rowi[k] * rowi[k];
        }
        crate::ensure!(
            s > 0.0,
            "matrix not positive definite at pivot {i} (s={s:.3e}); \
             increase Hessian dampening"
        );
        rowi[i] = s.sqrt();
    }
    Ok(l)
}

/// Solve A·x = b given the Cholesky factor L of A.
///
/// Both substitution passes read L row-wise (contiguous): the backward
/// pass is formulated as a rank-update sweep (`x[k] -= L[i][k]·x[i]`
/// over the prefix of row i) instead of the textbook strided column walk
/// `L[k][i]`, which would stride by n per element.
pub fn cholesky_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    // Forward: L·y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        let row = l.row(i);
        for k in 0..i {
            s -= row[k] * y[k];
        }
        y[i] = s / row[i];
    }
    // Backward: Lᵀ·x = y, column-oriented so row i of L is streamed once.
    let mut x = y;
    for i in (0..n).rev() {
        let row = l.row(i);
        let xi = x[i] / row[i];
        x[i] = xi;
        for k in 0..i {
            x[k] -= row[k] * xi;
        }
    }
    x
}

/// Extend a lower-triangular Cholesky factor **in place** from `k0`
/// factored rows to `k1`, inside a row-major buffer of row stride
/// `stride` (≥ `k1`). `a(i, j)` supplies the source-matrix entries on
/// demand (only the lower triangle `j ≤ i` of the new rows is read).
/// Returns `Err(CholFail)` naming the failing row when a new pivot is
/// not (numerically) positive.
///
/// This is the primitive behind the incremental trace-prefix database
/// builder: the pruned sets of one row trace are **nested prefixes**, so
/// the factor of `(H⁻¹)_P` at level ℓ is the leading `k_ℓ×k_ℓ` block of
/// the factor at every deeper level. Appending rows performs the *exact*
/// arithmetic — same values, same reduction order — that a from-scratch
/// factorization of the larger prefix would (row `i` of L only ever
/// reads rows `< i`), so `cholesky_append(0→k0)` then `(k0→k1)` is
/// bit-identical to one `cholesky_append(0→k1)`, which is itself
/// bit-identical to [`cholesky`] / the arena `chol_in_place` on the
/// gathered prefix (asserted by tests). Cost of producing all nested
/// levels collapses from Σ_ℓ k_ℓ³/3 to k_max³/3.
pub fn cholesky_append(
    l: &mut [f64],
    stride: usize,
    k0: usize,
    k1: usize,
    a: impl Fn(usize, usize) -> f64,
) -> Result<(), CholFail> {
    debug_assert!(k0 <= k1 && stride >= k1);
    debug_assert!(l.len() >= k1.saturating_sub(1) * stride + k1);
    for i in k0..k1 {
        for j in 0..i {
            let mut acc = a(i, j);
            for t in 0..j {
                acc -= l[i * stride + t] * l[j * stride + t];
            }
            l[i * stride + j] = acc / l[j * stride + j];
        }
        let mut acc = a(i, i);
        for t in 0..i {
            acc -= l[i * stride + t] * l[i * stride + t];
        }
        if !(acc > 0.0) {
            return Err(CholFail { row: i, diag: acc });
        }
        l[i * stride + i] = acc.sqrt();
    }
    Ok(())
}

/// Forward substitution `L·z = b` restricted to rows `k0..k1`, in place
/// on `b`, against a strided factor (the layout written by
/// [`cholesky_append`]). Like the factor itself, the forward solution is
/// **prefix-stable**: `z[i]` reads only `z[< i]`, so extending an
/// already-solved prefix performs the identical arithmetic a full
/// forward pass would — the incremental database builder carries `z`
/// across nested levels and only ever pays for the new rows.
pub fn cholesky_forward_strided(l: &[f64], stride: usize, k0: usize, k1: usize, b: &mut [f64]) {
    debug_assert!(k0 <= k1 && b.len() >= k1 && stride >= k1);
    for i in k0..k1 {
        let mut acc = b[i];
        for k in 0..i {
            acc -= l[i * stride + k] * b[k];
        }
        b[i] = acc / l[i * stride + i];
    }
}

/// Backward substitution `Lᵀ·x = z` in place on `b` (which holds `z`),
/// against `n` rows of a strided factor — the rank-update sweep over row
/// prefixes of [`cholesky_solve`]'s second pass. NOT prefix-stable
/// (row `i` updates every `x[< i]`): the incremental builder re-runs
/// only this Θ(n²) half per level.
pub fn cholesky_backward_strided(l: &[f64], stride: usize, n: usize, b: &mut [f64]) {
    debug_assert!(b.len() >= n && stride >= n);
    for i in (0..n).rev() {
        let xi = b[i] / l[i * stride + i];
        b[i] = xi;
        for k in 0..i {
            b[k] -= l[i * stride + k] * xi;
        }
    }
}

/// SPD solve `A·x = b` in place on `b`, given `n` factored rows of L in
/// a row-major buffer of row stride `stride` (the layout written by
/// [`cholesky_append`]). Arithmetic mirrors [`cholesky_solve`] exactly —
/// identical values in identical order, the stride only changes where
/// they live — so results are bit-identical for the same factor.
pub fn cholesky_solve_strided(l: &[f64], stride: usize, n: usize, b: &mut [f64]) {
    cholesky_forward_strided(l, stride, 0, n, b);
    cholesky_backward_strided(l, stride, n, b);
}

/// Panel width of the blocked factorization: the k0..k1 columns each
/// right-looking step factors before one tiled trailing update.
const CHOL_PANEL: usize = 48;
/// Tile edge of the trailing SYRK update (TILE×TILE blocks of the lower
/// triangle; ~2·48·64·8 B of operand per tile pair, L1/L2-resident).
const CHOL_TILE: usize = 64;
/// Dimension at which [`cholesky_inverse`] switches to the blocked
/// factorization. Below this the scalar [`cholesky`] is used, keeping
/// small-problem inverses bit-identical to the historical path (and to
/// the fixtures pinned against it); at and above it the reordered
/// trailing-update arithmetic is tolerance-pinned instead (see tests).
const CHOL_BLOCKED_MIN: usize = 128;

/// Cache-blocked right-looking Cholesky: factor a [`CHOL_PANEL`]-wide
/// panel with the scalar recurrence, triangular-solve the rows below it,
/// then apply the panel's contribution to the trailing lower triangle as
/// one tiled SYRK (`W[i][j] −= Σ_t W[i][t]·W[j][t]` over TILE×TILE
/// blocks — GEMM-shaped traffic that reuses each panel row TILE times,
/// versus the scalar loop's one long reduction per output).
///
/// Same factor as [`cholesky`] up to floating-point reassociation of the
/// trailing updates (each entry's reduction is split per panel instead
/// of running monolithically); agreement is pinned at 1e-12 relative by
/// tests, not bitwise. On a non-positive pivot returns the same
/// "not positive definite at pivot {i}" error shape as [`cholesky`],
/// with `i` the true failing row.
pub fn cholesky_blocked(a: &Mat) -> crate::util::error::Result<Mat> {
    crate::ensure!(a.rows == a.cols, "cholesky needs a square matrix");
    crate::span!("linalg.cholesky");
    let n = a.rows;
    let mut w = a.clone();
    let d = &mut w.data;
    let mut k0 = 0usize;
    while k0 < n {
        let k1 = (k0 + CHOL_PANEL).min(n);
        // 1. Factor the diagonal block in place (scalar, on values the
        //    previous trailing updates already reduced past column k0).
        for i in k0..k1 {
            for j in k0..i {
                let mut s = d[i * n + j];
                for t in k0..j {
                    s -= d[i * n + t] * d[j * n + t];
                }
                d[i * n + j] = s / d[j * n + j];
            }
            let mut s = d[i * n + i];
            for t in k0..i {
                s -= d[i * n + t] * d[i * n + t];
            }
            crate::ensure!(
                s > 0.0,
                "matrix not positive definite at pivot {i} (s={s:.3e}); \
                 increase Hessian dampening"
            );
            d[i * n + i] = s.sqrt();
        }
        // 2. Panel solve: rows below the block against its factor.
        for i in k1..n {
            for j in k0..k1 {
                let mut s = d[i * n + j];
                for t in k0..j {
                    s -= d[i * n + t] * d[j * n + t];
                }
                d[i * n + j] = s / d[j * n + j];
            }
        }
        // 3. Tiled SYRK trailing update on the lower triangle.
        let mut ib = k1;
        while ib < n {
            let iend = (ib + CHOL_TILE).min(n);
            let mut jb = k1;
            while jb < iend {
                let jend = (jb + CHOL_TILE).min(n);
                for i in ib..iend {
                    // Split: rows j < i readable while row i is written.
                    let (lo, hi) = d.split_at_mut(i * n);
                    let rowi = &mut hi[..n];
                    for j in jb..jend.min(i) {
                        let rowj = &lo[j * n + k0..j * n + k1];
                        let mut s = 0.0;
                        for (x, y) in rowi[k0..k1].iter().zip(rowj) {
                            s += x * y;
                        }
                        rowi[j] -= s;
                    }
                    // Diagonal entry (j == i) lives in rowi itself.
                    if i >= jb && i < jend {
                        let mut s = 0.0;
                        for x in &rowi[k0..k1] {
                            s += x * x;
                        }
                        rowi[i] -= s;
                    }
                }
                jb = jend;
            }
            ib = iend;
        }
        k0 = k1;
    }
    // Zero the strict upper triangle (stale copies of A).
    for i in 0..n {
        for v in w.data[i * n + i + 1..(i + 1) * n].iter_mut() {
            *v = 0.0;
        }
    }
    Ok(w)
}

/// [`cholesky_blocked`] on the mixed tier: phases 1–2 (diagonal-block
/// factor, panel solve) are **identical f64** — every pivot and every
/// panel entry is computed exactly as the f64 blocked factor computes
/// them *given its inputs* — and only phase 3, the memory-bound trailing
/// SYRK that streams the whole trailing triangle once per panel, reads a
/// once-narrowed f32 copy of the solved panel with f64 accumulators
/// (half the streamed bytes; the panel is ~n·48 entries, narrowed once
/// and reused across the whole trailing triangle). The factor therefore
/// differs from [`cholesky_blocked`] only by the f32 storage rounding of
/// the trailing updates, pinned at 1e-4 relative by tests; non-positive
/// pivots report the same true-row error shape.
pub fn cholesky_blocked_mixed(a: &Mat) -> crate::util::error::Result<Mat> {
    crate::ensure!(a.rows == a.cols, "cholesky needs a square matrix");
    crate::span!("linalg.cholesky");
    let n = a.rows;
    let mut w = a.clone();
    let d = &mut w.data;
    // f32 narrowing of the current panel strip (rows k1..n, columns
    // k0..k1), row-major at stride CHOL_PANEL; one allocation reused
    // across every panel step.
    let mut panel = vec![0.0f32; n * CHOL_PANEL];
    let mut k0 = 0usize;
    while k0 < n {
        let k1 = (k0 + CHOL_PANEL).min(n);
        let pw = k1 - k0;
        // 1. Factor the diagonal block in place (exact f64).
        for i in k0..k1 {
            for j in k0..i {
                let mut s = d[i * n + j];
                for t in k0..j {
                    s -= d[i * n + t] * d[j * n + t];
                }
                d[i * n + j] = s / d[j * n + j];
            }
            let mut s = d[i * n + i];
            for t in k0..i {
                s -= d[i * n + t] * d[i * n + t];
            }
            crate::ensure!(
                s > 0.0,
                "matrix not positive definite at pivot {i} (s={s:.3e}); \
                 increase Hessian dampening"
            );
            d[i * n + i] = s.sqrt();
        }
        // 2. Panel solve: rows below the block against its factor
        //    (exact f64).
        for i in k1..n {
            for j in k0..k1 {
                let mut s = d[i * n + j];
                for t in k0..j {
                    s -= d[i * n + t] * d[j * n + t];
                }
                d[i * n + j] = s / d[j * n + j];
            }
        }
        // Narrow the solved panel once; the trailing SYRK streams this
        // f32 copy instead of the f64 rows.
        for i in k1..n {
            let src = &d[i * n + k0..i * n + k1];
            let dst = &mut panel[(i - k1) * CHOL_PANEL..(i - k1) * CHOL_PANEL + pw];
            for (x, &v) in dst.iter_mut().zip(src) {
                *x = v as f32;
            }
        }
        // 3. Tiled SYRK trailing update, f32 loads / f64 accumulate.
        let mut ib = k1;
        while ib < n {
            let iend = (ib + CHOL_TILE).min(n);
            let mut jb = k1;
            while jb < iend {
                let jend = (jb + CHOL_TILE).min(n);
                for i in ib..iend {
                    let rowi = &panel[(i - k1) * CHOL_PANEL..(i - k1) * CHOL_PANEL + pw];
                    for j in jb..jend.min(i) {
                        let rowj = &panel[(j - k1) * CHOL_PANEL..(j - k1) * CHOL_PANEL + pw];
                        let mut s = 0.0f64;
                        for (x, y) in rowi.iter().zip(rowj) {
                            s += *x as f64 * *y as f64;
                        }
                        d[i * n + j] -= s;
                    }
                    if i >= jb && i < jend {
                        let mut s = 0.0f64;
                        for x in rowi {
                            let v = *x as f64;
                            s += v * v;
                        }
                        d[i * n + i] -= s;
                    }
                }
                jb = jend;
            }
            ib = iend;
        }
        k0 = k1;
    }
    for i in 0..n {
        for v in w.data[i * n + i + 1..(i + 1) * n].iter_mut() {
            *v = 0.0;
        }
    }
    Ok(w)
}

/// Full SPD inverse via Cholesky (A⁻¹ = L⁻ᵀ·L⁻¹). Large problems
/// (n ≥ [`CHOL_BLOCKED_MIN`]) factor through [`cholesky_blocked`] — or
/// [`cholesky_blocked_mixed`] when the **global** precision policy is
/// `mixed` (inverses feed shared/cached state — layer Hessians, trace
/// databases — so the per-job override deliberately does not reach this
/// choice); small ones keep the scalar factor bit-for-bit.
pub fn cholesky_inverse(a: &Mat) -> crate::util::error::Result<Mat> {
    use crate::util::precision::{global_precision, Precision};
    crate::span!("linalg.cholesky");
    let l = if a.rows >= CHOL_BLOCKED_MIN {
        match global_precision() {
            Precision::Mixed => cholesky_blocked_mixed(a)?,
            Precision::F64 => cholesky_blocked(a)?,
        }
    } else {
        cholesky(a)?
    };
    let n = a.rows;
    // Invert L (lower triangular) in place.
    let mut linv = Mat::zeros(n, n);
    for j in 0..n {
        linv.data[j * n + j] = 1.0 / l.at(j, j);
        for i in j + 1..n {
            let mut s = 0.0;
            for k in j..i {
                s -= l.at(i, k) * linv.at(k, j);
            }
            linv.data[i * n + j] = s / l.at(i, i);
        }
    }
    // A⁻¹ = Lᵀ⁻¹ L⁻¹ = linvᵀ · linv (linv is lower-triangular).
    let mut inv = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let mut s = 0.0;
            // sum over k >= max(i,j): linv[k][i] * linv[k][j]
            for k in j..n {
                s += linv.at(k, i) * linv.at(k, j);
            }
            inv.data[i * n + j] = s;
            inv.data[j * n + i] = s;
        }
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(n: usize, seed: u64) -> Mat {
        let x = Mat::randn(n, n + 4, seed);
        let mut h = x.xxt();
        h.add_diag(0.1);
        h
    }

    #[test]
    fn factor_reconstructs() {
        let a = spd(10, 1);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        assert!(a.dist(&rec) < 1e-8, "dist {}", a.dist(&rec));
    }

    #[test]
    fn solve_matches() {
        let a = spd(12, 2);
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..12).map(|i| (i as f64) - 3.0).collect();
        let x = cholesky_solve(&l, &b);
        let ax = a.matvec(&x);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn inverse_is_inverse() {
        let a = spd(15, 3);
        let inv = cholesky_inverse(&a).unwrap();
        let prod = a.matmul(&inv);
        assert!(prod.dist(&Mat::eye(15)) < 1e-7, "dist {}", prod.dist(&Mat::eye(15)));
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = Mat::eye(3);
        *a.at_mut(2, 2) = -1.0;
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn inverse_symmetric() {
        let a = spd(9, 4);
        let inv = cholesky_inverse(&a).unwrap();
        assert!(inv.dist(&inv.transpose()) < 1e-12);
    }

    /// LLᵀ must reconstruct a *real* layer Hessian (H = 2XXᵀ + λI from
    /// calibration-style inputs), not just synthetic SPD matrices.
    #[test]
    fn factor_reconstructs_layer_hessian() {
        use crate::compress::hessian::LayerHessian;
        let h = LayerHessian::from_inputs(&Mat::randn(20, 64, 11), 1e-8);
        let l = cholesky(&h.h).unwrap();
        let rec = l.matmul(&l.transpose());
        let scale = h.h.diag_mean().max(1.0);
        assert!(rec.dist(&h.h) < 1e-9 * scale, "dist {}", rec.dist(&h.h));
    }

    /// The append primitive must be bit-identical to the full factor:
    /// growing 0→k0→k1 in chunks equals one 0→k1 pass equals the
    /// Mat-based [`cholesky`] of the leading k1×k1 block, entry by
    /// entry — and every leading prefix of the grown factor IS the
    /// factor of that prefix.
    #[test]
    fn append_matches_full_factor_bitwise() {
        let n = 13;
        let a = spd(n, 7);
        let stride = n + 3; // deliberately over-wide buffer
        for split in [0usize, 1, 5, 12, 13] {
            let mut l = vec![f64::NAN; stride * n]; // dirty buffer
            assert!(cholesky_append(&mut l, stride, 0, split, |i, j| a.at(i, j)).is_ok());
            assert!(cholesky_append(&mut l, stride, split, n, |i, j| a.at(i, j)).is_ok());
            let full = cholesky(&a).unwrap();
            for i in 0..n {
                for j in 0..=i {
                    assert_eq!(
                        l[i * stride + j].to_bits(),
                        full.at(i, j).to_bits(),
                        "split {split}: L[{i}][{j}]"
                    );
                }
            }
        }
        // Prefix property: rows 0..k of the grown factor are the factor
        // of the leading k×k block.
        let mut l = vec![0.0; stride * n];
        assert!(cholesky_append(&mut l, stride, 0, n, |i, j| a.at(i, j)).is_ok());
        let k = 6;
        let idx: Vec<usize> = (0..k).collect();
        let prefix = cholesky(&a.submatrix(&idx, &idx)).unwrap();
        for i in 0..k {
            for j in 0..=i {
                assert_eq!(l[i * stride + j].to_bits(), prefix.at(i, j).to_bits());
            }
        }
    }

    /// The strided solve must be bit-identical to [`cholesky_solve`] on
    /// the same factor, and appending rows must not perturb solves
    /// against the shorter prefix.
    #[test]
    fn strided_solve_matches_mat_solve_bitwise() {
        let n = 11;
        let a = spd(n, 8);
        let stride = n + 2;
        let mut l = vec![0.0; stride * n];
        assert!(cholesky_append(&mut l, stride, 0, n, |i, j| a.at(i, j)).is_ok());
        let lm = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..n).map(|i| (i as f64) * 0.7 - 2.0).collect();
        let mut x = b.clone();
        cholesky_solve_strided(&l, stride, n, &mut x);
        let want = cholesky_solve(&lm, &b);
        assert_eq!(x, want);
        // Solve against the k=5 prefix: identical to factoring the 5×5
        // block from scratch and solving there.
        let k = 5;
        let idx: Vec<usize> = (0..k).collect();
        let lp = cholesky(&a.submatrix(&idx, &idx)).unwrap();
        let mut xp = b[..k].to_vec();
        cholesky_solve_strided(&l, stride, k, &mut xp);
        assert_eq!(xp, cholesky_solve(&lp, &b[..k]));
    }

    /// The forward solution is prefix-stable: extending rows k0→k1 on a
    /// carried z equals a full forward pass, bitwise; forward+backward
    /// composed equals the one-shot strided solve.
    #[test]
    fn forward_extension_is_prefix_stable_bitwise() {
        let n = 10;
        let a = spd(n, 9);
        let stride = n + 1;
        let mut l = vec![0.0; stride * n];
        assert!(cholesky_append(&mut l, stride, 0, n, |i, j| a.at(i, j)).is_ok());
        let b: Vec<f64> = (0..n).map(|i| (i as f64) * 1.3 - 4.0).collect();
        // Extended in three chunks...
        let mut z = b.clone();
        cholesky_forward_strided(&l, stride, 0, 4, &mut z);
        cholesky_forward_strided(&l, stride, 4, 7, &mut z);
        cholesky_forward_strided(&l, stride, 7, n, &mut z);
        // ...equals one pass...
        let mut z1 = b.clone();
        cholesky_forward_strided(&l, stride, 0, n, &mut z1);
        assert_eq!(z, z1);
        // ...and backward on the carried z equals the one-shot solve.
        let mut x = z;
        cholesky_backward_strided(&l, stride, n, &mut x);
        let mut x1 = b.clone();
        cholesky_solve_strided(&l, stride, n, &mut x1);
        assert_eq!(x, x1);
    }

    /// The append failure names the true failing row (not merely "some
    /// pivot failed") and carries the offending reduced diagonal.
    #[test]
    fn append_rejects_indefinite_pivot() {
        let mut a = Mat::eye(3);
        *a.at_mut(2, 2) = -1.0;
        let mut l = vec![0.0; 9];
        assert!(cholesky_append(&mut l, 3, 0, 2, |i, j| a.at(i, j)).is_ok());
        let fail = cholesky_append(&mut l, 3, 2, 3, |i, j| a.at(i, j)).unwrap_err();
        assert_eq!(fail.row, 2);
        assert!(fail.diag < 0.0 && fail.diag.is_finite(), "diag {}", fail.diag);
    }

    /// The blocked factor must agree with the scalar factor across panel
    /// boundaries (reassociated trailing updates → tolerance, not bits).
    #[test]
    fn blocked_factor_matches_scalar() {
        for &(n, seed) in &[(30usize, 21u64), (70, 22), (150, 23)] {
            let a = spd(n, seed);
            let ls = cholesky(&a).unwrap();
            let lb = cholesky_blocked(&a).unwrap();
            for i in 0..n {
                for j in 0..n {
                    let (s, b) = (ls.at(i, j), lb.at(i, j));
                    assert!(
                        (s - b).abs() <= 1e-12 * (1.0 + s.abs()),
                        "n={n} L[{i}][{j}]: {b} vs scalar {s}"
                    );
                }
            }
        }
    }

    /// The mixed blocked factor (f32 trailing-update storage, f64
    /// accumulate) must agree with the scalar factor at the f32 storage
    /// tolerance across panel boundaries, including sizes where multiple
    /// trailing panels compound the rounding.
    #[test]
    fn mixed_blocked_factor_matches_scalar_within_tolerance() {
        for &(n, seed) in &[(30usize, 21u64), (70, 22), (150, 23)] {
            let a = spd(n, seed);
            let ls = cholesky(&a).unwrap();
            let lm = cholesky_blocked_mixed(&a).unwrap();
            for i in 0..n {
                for j in 0..n {
                    let (s, m) = (ls.at(i, j), lm.at(i, j));
                    assert!(
                        (s - m).abs() <= 1e-4 * (1.0 + s.abs()),
                        "n={n} L[{i}][{j}]: {m} vs scalar {s}"
                    );
                }
            }
        }
    }

    /// Mixed blocked rejection names the true failing pivot too.
    #[test]
    fn mixed_blocked_rejects_with_true_pivot() {
        let mut a = spd(60, 24);
        *a.at_mut(53, 53) = -4.0;
        let err = cholesky_blocked_mixed(&a).unwrap_err();
        assert!(err.to_string().contains("pivot 53"), "{err}");
    }

    /// Blocked rejection names the true failing pivot, like the scalar
    /// path does.
    #[test]
    fn blocked_rejects_with_true_pivot() {
        let mut a = spd(60, 24);
        *a.at_mut(53, 53) = -4.0; // beyond the first panel
        let err = cholesky_blocked(&a).unwrap_err();
        assert!(err.to_string().contains("pivot 53"), "{err}");
    }

    /// n ≥ CHOL_BLOCKED_MIN routes `cholesky_inverse` through the
    /// blocked factor; the inverse contract must hold there too.
    #[test]
    fn inverse_via_blocked_factor() {
        let n = CHOL_BLOCKED_MIN + 2;
        let a = spd(n, 25);
        let inv = cholesky_inverse(&a).unwrap();
        let prod = a.matmul(&inv);
        let dist = prod.dist(&Mat::eye(n));
        assert!(dist < 1e-6, "dist {dist}");
    }

    /// cholesky_solve must agree with the independent Gauss–Jordan
    /// inverse route (A⁻¹·b) on a layer Hessian.
    #[test]
    fn solve_matches_gauss_jordan_inverse_route() {
        use crate::compress::hessian::LayerHessian;
        use crate::linalg::gauss_jordan_inverse;
        let h = LayerHessian::from_inputs(&Mat::randn(16, 48, 12), 1e-8);
        let b: Vec<f64> = (0..16).map(|i| ((i * 7) % 5) as f64 - 2.0).collect();
        let l = cholesky(&h.h).unwrap();
        let x1 = cholesky_solve(&l, &b);
        let inv = gauss_jordan_inverse(&h.h).unwrap();
        let x2 = inv.matvec(&b);
        for (a, c) in x1.iter().zip(&x2) {
            assert!((a - c).abs() < 1e-8 * c.abs().max(1.0), "{a} vs {c}");
        }
    }
}
