//! Cholesky decomposition, SPD solve and SPD inverse.
//!
//! The layer Hessian H = 2XXᵀ (+ dampening) is symmetric positive
//! definite, so its inverse — the quantity every OBS formula consumes —
//! is computed via Cholesky: numerically stable and ~2× cheaper than
//! Gauss–Jordan.

use super::Mat;

/// Lower-triangular Cholesky factor L with A = L·Lᵀ.
/// Returns Err if A is not (numerically) positive definite.
///
/// The inner reductions run over contiguous row prefixes of L (row-major
/// slices, no strided column walks), so the Θ(n³) loop streams through
/// cache lines instead of jumping a full row width per element.
pub fn cholesky(a: &Mat) -> crate::util::error::Result<Mat> {
    crate::ensure!(a.rows == a.cols, "cholesky needs a square matrix");
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        // Split so rows 0..i are readable while row i is written.
        let (done, cur) = l.data.split_at_mut(i * n);
        let rowi = &mut cur[..n];
        for j in 0..i {
            let rowj = &done[j * n..j * n + n];
            let mut s = a.at(i, j);
            for k in 0..j {
                s -= rowi[k] * rowj[k];
            }
            rowi[j] = s / rowj[j];
        }
        let mut s = a.at(i, i);
        for k in 0..i {
            s -= rowi[k] * rowi[k];
        }
        crate::ensure!(
            s > 0.0,
            "matrix not positive definite at pivot {i} (s={s:.3e}); \
             increase Hessian dampening"
        );
        rowi[i] = s.sqrt();
    }
    Ok(l)
}

/// Solve A·x = b given the Cholesky factor L of A.
///
/// Both substitution passes read L row-wise (contiguous): the backward
/// pass is formulated as a rank-update sweep (`x[k] -= L[i][k]·x[i]`
/// over the prefix of row i) instead of the textbook strided column walk
/// `L[k][i]`, which would stride by n per element.
pub fn cholesky_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    // Forward: L·y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        let row = l.row(i);
        for k in 0..i {
            s -= row[k] * y[k];
        }
        y[i] = s / row[i];
    }
    // Backward: Lᵀ·x = y, column-oriented so row i of L is streamed once.
    let mut x = y;
    for i in (0..n).rev() {
        let row = l.row(i);
        let xi = x[i] / row[i];
        x[i] = xi;
        for k in 0..i {
            x[k] -= row[k] * xi;
        }
    }
    x
}

/// Full SPD inverse via Cholesky (A⁻¹ = L⁻ᵀ·L⁻¹).
pub fn cholesky_inverse(a: &Mat) -> crate::util::error::Result<Mat> {
    let l = cholesky(a)?;
    let n = a.rows;
    // Invert L (lower triangular) in place.
    let mut linv = Mat::zeros(n, n);
    for j in 0..n {
        linv.data[j * n + j] = 1.0 / l.at(j, j);
        for i in j + 1..n {
            let mut s = 0.0;
            for k in j..i {
                s -= l.at(i, k) * linv.at(k, j);
            }
            linv.data[i * n + j] = s / l.at(i, i);
        }
    }
    // A⁻¹ = Lᵀ⁻¹ L⁻¹ = linvᵀ · linv (linv is lower-triangular).
    let mut inv = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let mut s = 0.0;
            // sum over k >= max(i,j): linv[k][i] * linv[k][j]
            for k in j..n {
                s += linv.at(k, i) * linv.at(k, j);
            }
            inv.data[i * n + j] = s;
            inv.data[j * n + i] = s;
        }
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(n: usize, seed: u64) -> Mat {
        let x = Mat::randn(n, n + 4, seed);
        let mut h = x.xxt();
        h.add_diag(0.1);
        h
    }

    #[test]
    fn factor_reconstructs() {
        let a = spd(10, 1);
        let l = cholesky(&a).unwrap();
        let rec = l.matmul(&l.transpose());
        assert!(a.dist(&rec) < 1e-8, "dist {}", a.dist(&rec));
    }

    #[test]
    fn solve_matches() {
        let a = spd(12, 2);
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..12).map(|i| (i as f64) - 3.0).collect();
        let x = cholesky_solve(&l, &b);
        let ax = a.matvec(&x);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn inverse_is_inverse() {
        let a = spd(15, 3);
        let inv = cholesky_inverse(&a).unwrap();
        let prod = a.matmul(&inv);
        assert!(prod.dist(&Mat::eye(15)) < 1e-7, "dist {}", prod.dist(&Mat::eye(15)));
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = Mat::eye(3);
        *a.at_mut(2, 2) = -1.0;
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn inverse_symmetric() {
        let a = spd(9, 4);
        let inv = cholesky_inverse(&a).unwrap();
        assert!(inv.dist(&inv.transpose()) < 1e-12);
    }

    /// LLᵀ must reconstruct a *real* layer Hessian (H = 2XXᵀ + λI from
    /// calibration-style inputs), not just synthetic SPD matrices.
    #[test]
    fn factor_reconstructs_layer_hessian() {
        use crate::compress::hessian::LayerHessian;
        let h = LayerHessian::from_inputs(&Mat::randn(20, 64, 11), 1e-8);
        let l = cholesky(&h.h).unwrap();
        let rec = l.matmul(&l.transpose());
        let scale = h.h.diag_mean().max(1.0);
        assert!(rec.dist(&h.h) < 1e-9 * scale, "dist {}", rec.dist(&h.h));
    }

    /// cholesky_solve must agree with the independent Gauss–Jordan
    /// inverse route (A⁻¹·b) on a layer Hessian.
    #[test]
    fn solve_matches_gauss_jordan_inverse_route() {
        use crate::compress::hessian::LayerHessian;
        use crate::linalg::gauss_jordan_inverse;
        let h = LayerHessian::from_inputs(&Mat::randn(16, 48, 12), 1e-8);
        let b: Vec<f64> = (0..16).map(|i| ((i * 7) % 5) as f64 - 2.0).collect();
        let l = cholesky(&h.h).unwrap();
        let x1 = cholesky_solve(&l, &b);
        let inv = gauss_jordan_inverse(&h.h).unwrap();
        let x2 = inv.matvec(&b);
        for (a, c) in x1.iter().zip(&x2) {
            assert!((a - c).abs() < 1e-8 * c.abs().max(1.0), "{a} vs {c}");
        }
    }
}
