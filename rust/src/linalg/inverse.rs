//! General matrix inverse (Gauss–Jordan with partial pivoting) and the
//! paper's Lemma 1: O(d²) row/column removal update of an inverse.

use super::Mat;

/// Invert a general square matrix via Gauss–Jordan with partial pivoting.
/// Used for the small c×c block matrices in block-sparsity (Eq. 5) and as
/// an independent cross-check of `cholesky_inverse` in tests.
pub fn gauss_jordan_inverse(a: &Mat) -> crate::util::error::Result<Mat> {
    crate::ensure!(a.rows == a.cols, "inverse needs a square matrix");
    let n = a.rows;
    let mut m = a.clone();
    let mut inv = Mat::eye(n);
    for col in 0..n {
        // Partial pivot.
        let mut piv = col;
        let mut best = m.at(col, col).abs();
        for r in col + 1..n {
            let v = m.at(r, col).abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        crate::ensure!(best > 1e-300, "singular matrix at column {col}");
        if piv != col {
            for c in 0..n {
                let t = m.at(col, c);
                *m.at_mut(col, c) = m.at(piv, c);
                *m.at_mut(piv, c) = t;
                let t = inv.at(col, c);
                *inv.at_mut(col, c) = inv.at(piv, c);
                *inv.at_mut(piv, c) = t;
            }
        }
        let d = m.at(col, col);
        for c in 0..n {
            *m.at_mut(col, c) /= d;
            *inv.at_mut(col, c) /= d;
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = m.at(r, col);
            if f == 0.0 {
                continue;
            }
            for c in 0..n {
                let mv = m.at(col, c);
                *m.at_mut(r, c) -= f * mv;
                let iv = inv.at(col, c);
                *inv.at_mut(r, c) -= f * iv;
            }
        }
    }
    Ok(inv)
}

/// **Lemma 1 (Row & Column Removal).** Given H⁻¹, compute the inverse of
/// H with row and column p removed:
///
///   (H₋ₚ)⁻¹ = ( H⁻¹ − H⁻¹:,ₚ · H⁻¹ₚ,: / [H⁻¹]ₚₚ )₋ₚ
///
/// This function performs the rank-1 Gaussian-elimination step **in
/// place** and leaves row/column p zeroed (diag set to the eliminated
/// pivot's reciprocal magnitude is NOT preserved — it is zeroed too, and
/// callers must never read it again), exactly as Algorithm 1 requires:
/// the matrix is not resized so that weight indices stay stable.
///
/// Returns the pivot value [H⁻¹]ₚₚ that was eliminated.
pub fn remove_row_col(hinv: &mut Mat, p: usize) -> f64 {
    let mut rowbuf = Vec::new();
    remove_row_col_into(hinv, p, &mut rowbuf)
}

/// [`remove_row_col`] with a caller-owned pivot-row buffer, for loops
/// that eliminate many indices on a full-width matrix (e.g. the sparse
/// OBQ pre-elimination): `rowbuf` is grown once and reused, so
/// steady-state eliminations perform zero heap allocation. The
/// compacted arena engine has its own fused elimination
/// (`compress::sweep`); this is the full-width form. The column-p entry of
/// each row is read *in place* immediately before that row's update
/// (rows are processed top-down, so the value is still pristine) —
/// the historical separate column copy was pure waste.
pub fn remove_row_col_into(hinv: &mut Mat, p: usize, rowbuf: &mut Vec<f64>) -> f64 {
    let n = hinv.rows;
    debug_assert_eq!(n, hinv.cols);
    let d = hinv.at(p, p);
    debug_assert!(d != 0.0, "eliminating an already-eliminated index");
    if rowbuf.len() < n {
        rowbuf.resize(n, 0.0);
    }
    rowbuf[..n].copy_from_slice(hinv.row(p));
    let rowp = &rowbuf[..n];
    let inv_d = 1.0 / d;
    // The rank-1 subtraction streams the matrix once, row by row, each
    // row a contiguous slice zipped against the cached pivot row — the
    // Θ(d²) inner loop of Algorithm 1 is pure unit-stride traffic.
    for row in hinv.data.chunks_exact_mut(n) {
        let cr = row[p];
        if cr == 0.0 {
            continue; // already-eliminated row: the update is a no-op
        }
        let f = cr * inv_d;
        for (x, &rp) in row.iter_mut().zip(rowp) {
            *x -= f * rp;
        }
    }
    // Numerical hygiene: force the eliminated row/col to exact zero.
    for r in 0..n {
        *hinv.at_mut(r, p) = 0.0;
        *hinv.at_mut(p, r) = 0.0;
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky_inverse;

    fn spd(n: usize, seed: u64) -> Mat {
        let x = Mat::randn(n, n + 6, seed);
        let mut h = x.xxt();
        h.add_diag(0.05);
        h
    }

    #[test]
    fn gj_inverse_matches_cholesky() {
        let a = spd(12, 7);
        let gi = gauss_jordan_inverse(&a).unwrap();
        let ci = cholesky_inverse(&a).unwrap();
        assert!(gi.dist(&ci) < 1e-7);
    }

    #[test]
    fn gj_rejects_singular() {
        let a = Mat::zeros(3, 3);
        assert!(gauss_jordan_inverse(&a).is_err());
    }

    /// Lemma 1 — the central exactness claim of the paper: the rank-1
    /// elimination of (p,p) in H⁻¹ must equal the fresh inverse of H with
    /// row/col p deleted.
    #[test]
    fn lemma1_matches_fresh_inverse() {
        for seed in 0..5u64 {
            let n = 10;
            let h = spd(n, 100 + seed);
            let mut hinv = cholesky_inverse(&h).unwrap();
            let p = (seed as usize) % n;
            remove_row_col(&mut hinv, p);

            // Fresh inverse of H with row/col p removed.
            let keep: Vec<usize> = (0..n).filter(|&i| i != p).collect();
            let hsub = h.submatrix(&keep, &keep);
            let fresh = cholesky_inverse(&hsub).unwrap();

            let upd = hinv.submatrix(&keep, &keep);
            assert!(
                upd.dist(&fresh) < 1e-7,
                "seed {seed} p {p}: dist {}",
                upd.dist(&fresh)
            );
        }
    }

    /// Successive eliminations must also stay exact (Algorithm 1 applies
    /// Lemma 1 once per pruned weight).
    #[test]
    fn lemma1_chains() {
        let n = 12;
        let h = spd(n, 42);
        let mut hinv = cholesky_inverse(&h).unwrap();
        let kill = [3usize, 7, 0, 9];
        for &p in &kill {
            remove_row_col(&mut hinv, p);
        }
        let keep: Vec<usize> = (0..n).filter(|i| !kill.contains(i)).collect();
        let fresh = cholesky_inverse(&h.submatrix(&keep, &keep)).unwrap();
        let upd = hinv.submatrix(&keep, &keep);
        assert!(upd.dist(&fresh) < 1e-6, "dist {}", upd.dist(&fresh));
    }

    /// Lemma 1 on a *real* layer Hessian inverse (H = 2XXᵀ + λI from
    /// calibration-style inputs): the in-place elimination must match a
    /// fresh inverse of the submatrix with the row/col deleted.
    #[test]
    fn lemma1_matches_submatrix_rebuild_on_layer_hessian() {
        use crate::compress::hessian::LayerHessian;
        let n = 14;
        let h = LayerHessian::from_inputs(&Mat::randn(n, 44, 31), 1e-8);
        let mut hinv = h.hinv.clone();
        for &p in &[2usize, 9, 5] {
            remove_row_col(&mut hinv, p);
        }
        let keep: Vec<usize> = (0..n).filter(|i| ![2usize, 9, 5].contains(i)).collect();
        let fresh = cholesky_inverse(&h.h.submatrix(&keep, &keep)).unwrap();
        let upd = hinv.submatrix(&keep, &keep);
        let scale = fresh.diag_mean().abs().max(1e-12);
        assert!(upd.dist(&fresh) < 1e-6 * scale.max(1.0), "dist {}", upd.dist(&fresh));
    }

    #[test]
    fn remove_returns_pivot() {
        let h = spd(5, 9);
        let mut hinv = cholesky_inverse(&h).unwrap();
        let d = hinv.at(2, 2);
        let got = remove_row_col(&mut hinv, 2);
        assert_eq!(d, got);
        // Row/col zeroed.
        for i in 0..5 {
            assert_eq!(hinv.at(i, 2), 0.0);
            assert_eq!(hinv.at(2, i), 0.0);
        }
    }
}
