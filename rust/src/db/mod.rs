//! Model database + stitcher.
//!
//! The paper's non-uniform pipeline ("model database" in Section 6):
//! every layer is compressed *independently* to every candidate level;
//! the database stores the compressed weights and the layer-wise
//! calibration loss. Mixed-compression models are then "simply stitched
//! together from layer-wise results" for whatever constraint the solver
//! produces — no recompression needed when targets change (the key
//! flexibility argument vs sequential methods like AdaRound/BRECQ).

use crate::cost::Level;
use crate::linalg::Mat;
use crate::nn::CompressibleModel;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// One database entry: a layer compressed to a level.
///
/// Weights are stored as f32 (the inference dtype) — the database holds
/// every (layer × level) combination, so at f64 a single model's DB
/// would double the resident footprint for no accuracy benefit.
#[derive(Debug, Clone)]
pub struct Entry {
    pub layer: String,
    pub level: Level,
    /// Compressed weights, f32, row-major [rows × cols].
    pub w: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
    /// Layer-wise squared error on the calibration Hessian.
    pub sq_err: f64,
}

impl Entry {
    pub fn from_mat(layer: &str, level: Level, w: &Mat, sq_err: f64) -> Entry {
        Entry {
            layer: layer.to_string(),
            level,
            w: w.to_f32(),
            rows: w.rows,
            cols: w.cols,
            sq_err,
        }
    }

    pub fn to_mat(&self) -> Mat {
        Mat::from_f32(self.rows, self.cols, &self.w)
    }

    /// Approximate resident size of this entry (weights dominate) —
    /// the unit of the engine's LRU database-cache accounting.
    pub fn bytes(&self) -> usize {
        std::mem::size_of::<Entry>() + self.layer.len() + self.w.len() * 4
    }
}

/// Renders `Level::key()` into a stack buffer so lookups can borrow the
/// key as `&str` without a heap allocation. Identity is the *exact*
/// legacy string (same `{:.3}` formatting, same rounding), so level
/// dedup behaves bit-for-bit as the old flat string-keyed map did.
struct StackKey {
    buf: [u8; 48],
    len: usize,
}

impl StackKey {
    fn of(level: &Level) -> StackKey {
        use std::fmt::Write;
        let mut k = StackKey { buf: [0u8; 48], len: 0 };
        write!(
            k,
            "s{:.3}_w{}a{}{}",
            level.sparsity,
            level.w_bits,
            level.a_bits,
            if level.is_24 { "_24" } else { "" }
        )
        .expect("level key fits the stack buffer");
        k
    }

    fn as_str(&self) -> &str {
        // Only ASCII from the fmt above.
        std::str::from_utf8(&self.buf[..self.len]).expect("ascii key")
    }
}

impl std::fmt::Write for StackKey {
    fn write_str(&mut self, s: &str) -> std::fmt::Result {
        let end = self.len + s.len();
        if end > self.buf.len() {
            return Err(std::fmt::Error);
        }
        self.buf[self.len..end].copy_from_slice(s.as_bytes());
        self.len = end;
        Ok(())
    }
}

/// The database: layer → (level-key → entry).
///
/// The nesting is the lookup hot path: `get` is two map probes with
/// **zero allocation** (the old flat `(String, String)` key forced a
/// fresh `String` pair per probe; the level key is now rendered into a
/// [`StackKey`] and borrowed), and `levels_for` walks one layer's
/// subtree instead of string-comparing every entry in the database.
#[derive(Default)]
pub struct ModelDb {
    pub model: String,
    layers: BTreeMap<String, BTreeMap<String, Entry>>,
}

impl ModelDb {
    pub fn new(model: &str) -> ModelDb {
        ModelDb { model: model.to_string(), layers: BTreeMap::new() }
    }

    pub fn insert(&mut self, e: Entry) {
        self.layers
            .entry(e.layer.clone())
            .or_default()
            .insert(e.level.key(), e);
    }

    pub fn get(&self, layer: &str, level: &Level) -> Option<&Entry> {
        self.layers.get(layer)?.get(StackKey::of(level).as_str())
    }

    pub fn len(&self) -> usize {
        self.layers.values().map(|m| m.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.values().all(|m| m.is_empty())
    }

    /// Approximate resident size (entry weights dominate) — what the
    /// engine's LRU cache charges a cached database against its budget.
    pub fn bytes(&self) -> usize {
        self.model.len()
            + self
                .layers
                .iter()
                .map(|(l, m)| l.len() + m.values().map(Entry::bytes).sum::<usize>())
                .sum::<usize>()
    }

    /// Every entry in deterministic (layer, level-key) order — the
    /// iteration order of the snapshot format (`crate::store`), so two
    /// databases with identical contents serialize byte-identically.
    pub fn entries(&self) -> impl Iterator<Item = &Entry> + '_ {
        self.layers.values().flat_map(|m| m.values())
    }

    /// Levels available for a layer, with losses (solver input). One
    /// subtree walk; no per-entry string compares.
    pub fn levels_for(&self, layer: &str) -> Vec<(&Level, f64)> {
        self.layers
            .get(layer)
            .map(|m| m.values().map(|e| (&e.level, e.sq_err)).collect())
            .unwrap_or_default()
    }

    /// Stitch a model: write each layer's chosen level into a clone of
    /// the dense model. Layers not mentioned stay dense.
    pub fn stitch(
        &self,
        dense: &dyn CompressibleModel,
        assignment: &[(String, Level)],
    ) -> Box<dyn CompressibleModel> {
        let mut m = dense.clone_box();
        for (layer, level) in assignment {
            let e = self
                .get(layer, level)
                .unwrap_or_else(|| panic!("db missing ({layer}, {})", level.key()));
            m.set_weight(layer, &e.to_mat());
            m.set_act_bits(layer, level.a_bits);
        }
        m
    }

    /// Summary (losses only — weights stay in memory) as JSON, for the
    /// experiment logs.
    pub fn summary_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("model", self.model.as_str());
        let mut obj = Json::obj();
        for (layer, levels) in &self.layers {
            let v: Vec<Json> = levels
                .values()
                .map(|e| {
                    let mut o = Json::obj();
                    o.set("level", e.level.key().as_str())
                        .set("sq_err", e.sq_err)
                        .set("sparsity", e.level.sparsity);
                    o
                })
                .collect();
            obj.set(layer, Json::Arr(v));
        }
        root.set("layers", obj);
        root
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::cnn::tests::fake_resnet_bundle;
    use crate::nn::cnn::CnnModel;

    fn level(s: f64) -> Level {
        Level { sparsity: s, ..Level::dense() }
    }

    #[test]
    fn insert_get_levels() {
        let mut db = ModelDb::new("m");
        db.insert(Entry::from_mat("a", level(0.5), &Mat::zeros(2, 2), 1.0));
        db.insert(Entry::from_mat("a", level(0.75), &Mat::zeros(2, 2), 3.0));
        db.insert(Entry::from_mat("b", level(0.5), &Mat::zeros(2, 2), 0.5));
        assert_eq!(db.len(), 3);
        let ls = db.levels_for("a");
        assert_eq!(ls.len(), 2);
        assert!(db.get("a", &level(0.75)).is_some());
        assert!(db.get("a", &level(0.9)).is_none());
        // entries() walks every (layer, level) in deterministic order.
        let keys: Vec<(String, String)> = db
            .entries()
            .map(|e| (e.layer.clone(), e.level.key()))
            .collect();
        assert_eq!(keys.len(), 3);
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted, "entries() is sorted by (layer, level key)");
    }

    #[test]
    fn stitch_writes_layers() {
        let dense = CnnModel::resnet("rneta", &fake_resnet_bundle(1)).unwrap();
        let mut db = ModelDb::new("rneta");
        let name = "s0.b0.conv1";
        let w0 = dense.get_weight(name);
        db.insert(Entry::from_mat(name, level(1.0), &Mat::zeros(w0.rows, w0.cols), 9.0));
        let stitched = db.stitch(&dense, &[(name.to_string(), level(1.0))]);
        assert!(stitched.get_weight(name).data.iter().all(|&v| v == 0.0));
        // Dense model untouched.
        assert!(dense.get_weight(name).data.iter().any(|&v| v != 0.0));
    }

    /// The nested map must collapse level spellings at the same
    /// granularity as the legacy string key ("s{:.3}...") — same-key
    /// inserts overwrite, distinct grid levels stay distinct.
    #[test]
    fn level_key_granularity_matches_legacy_string_key() {
        let mut db = ModelDb::new("m");
        db.insert(Entry::from_mat("a", level(0.5), &Mat::zeros(1, 1), 1.0));
        // Same millisparsity → same key → overwrite, like "s0.500".
        db.insert(Entry::from_mat("a", level(0.5000004), &Mat::zeros(1, 1), 2.0));
        assert_eq!(db.len(), 1);
        assert_eq!(db.get("a", &level(0.5)).unwrap().sq_err, 2.0);
        // Adjacent Eq. 10 grid levels resolve to distinct keys.
        let grid = crate::solver::sparsity_grid(0.1, 0.95);
        let mut db2 = ModelDb::new("m");
        for &s in &grid {
            db2.insert(Entry::from_mat("a", level(s), &Mat::zeros(1, 1), s));
        }
        assert_eq!(db2.len(), grid.len());
        for &s in &grid {
            assert_eq!(db2.get("a", &level(s)).unwrap().sq_err, s);
        }
    }

    #[test]
    fn levels_for_scoped_to_one_layer() {
        let mut db = ModelDb::new("m");
        db.insert(Entry::from_mat("a", level(0.5), &Mat::zeros(2, 2), 1.0));
        db.insert(Entry::from_mat("ab", level(0.5), &Mat::zeros(2, 2), 2.0));
        db.insert(Entry::from_mat("b", level(0.5), &Mat::zeros(2, 2), 3.0));
        let ls = db.levels_for("a");
        assert_eq!(ls.len(), 1);
        assert_eq!(ls[0].1, 1.0);
        assert!(db.levels_for("nope").is_empty());
    }

    #[test]
    fn bytes_tracks_entry_payload() {
        let mut db = ModelDb::new("m");
        assert_eq!(db.bytes(), 1);
        db.insert(Entry::from_mat("a", level(0.5), &Mat::zeros(8, 8), 1.0));
        let one = db.bytes();
        assert!(one >= 8 * 8 * 4, "weights accounted: {one}");
        db.insert(Entry::from_mat("b", level(0.5), &Mat::zeros(8, 8), 1.0));
        assert!(db.bytes() > one, "second entry adds bytes");
        // Overwriting the same (layer, level) must not double-count.
        let two = db.bytes();
        db.insert(Entry::from_mat("b", level(0.5), &Mat::zeros(8, 8), 2.0));
        assert_eq!(db.bytes(), two);
    }

    #[test]
    fn summary_json_roundtrips() {
        let mut db = ModelDb::new("m");
        db.insert(Entry::from_mat("a", level(0.5), &Mat::zeros(1, 1), 2.0));
        let s = db.summary_json().to_string_pretty();
        let back = crate::util::json::parse(&s).unwrap();
        assert_eq!(back.req_str("model").unwrap(), "m");
    }
}
