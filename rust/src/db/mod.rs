//! Model database + stitcher.
//!
//! The paper's non-uniform pipeline ("model database" in Section 6):
//! every layer is compressed *independently* to every candidate level;
//! the database stores the compressed weights and the layer-wise
//! calibration loss. Mixed-compression models are then "simply stitched
//! together from layer-wise results" for whatever constraint the solver
//! produces — no recompression needed when targets change (the key
//! flexibility argument vs sequential methods like AdaRound/BRECQ).

use crate::cost::Level;
use crate::linalg::Mat;
use crate::nn::CompressibleModel;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// One database entry: a layer compressed to a level.
///
/// Weights are stored as f32 (the inference dtype) — the database holds
/// every (layer × level) combination, so at f64 a single model's DB
/// would double the resident footprint for no accuracy benefit.
#[derive(Debug, Clone)]
pub struct Entry {
    pub layer: String,
    pub level: Level,
    /// Compressed weights, f32, row-major [rows × cols].
    pub w: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
    /// Layer-wise squared error on the calibration Hessian.
    pub sq_err: f64,
}

impl Entry {
    pub fn from_mat(layer: &str, level: Level, w: &Mat, sq_err: f64) -> Entry {
        Entry {
            layer: layer.to_string(),
            level,
            w: w.to_f32(),
            rows: w.rows,
            cols: w.cols,
            sq_err,
        }
    }

    pub fn to_mat(&self) -> Mat {
        Mat::from_f32(self.rows, self.cols, &self.w)
    }
}

/// The database: (layer, level-key) → entry.
#[derive(Default)]
pub struct ModelDb {
    pub model: String,
    entries: BTreeMap<(String, String), Entry>,
}

impl ModelDb {
    pub fn new(model: &str) -> ModelDb {
        ModelDb { model: model.to_string(), entries: BTreeMap::new() }
    }

    pub fn insert(&mut self, e: Entry) {
        self.entries.insert((e.layer.clone(), e.level.key()), e);
    }

    pub fn get(&self, layer: &str, level: &Level) -> Option<&Entry> {
        self.entries.get(&(layer.to_string(), level.key()))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Levels available for a layer, with losses (solver input).
    pub fn levels_for(&self, layer: &str) -> Vec<(&Level, f64)> {
        self.entries
            .iter()
            .filter(|((l, _), _)| l == layer)
            .map(|(_, e)| (&e.level, e.sq_err))
            .collect()
    }

    /// Stitch a model: write each layer's chosen level into a clone of
    /// the dense model. Layers not mentioned stay dense.
    pub fn stitch(
        &self,
        dense: &dyn CompressibleModel,
        assignment: &[(String, Level)],
    ) -> Box<dyn CompressibleModel> {
        let mut m = dense.clone_box();
        for (layer, level) in assignment {
            let e = self
                .get(layer, level)
                .unwrap_or_else(|| panic!("db missing ({layer}, {})", level.key()));
            m.set_weight(layer, &e.to_mat());
            m.set_act_bits(layer, level.a_bits);
        }
        m
    }

    /// Summary (losses only — weights stay in memory) as JSON, for the
    /// experiment logs.
    pub fn summary_json(&self) -> Json {
        let mut layers: BTreeMap<String, Vec<Json>> = BTreeMap::new();
        for ((layer, key), e) in &self.entries {
            let mut o = Json::obj();
            o.set("level", key.as_str()).set("sq_err", e.sq_err).set(
                "sparsity",
                e.level.sparsity,
            );
            layers.entry(layer.clone()).or_default().push(o);
        }
        let mut root = Json::obj();
        root.set("model", self.model.as_str());
        let mut obj = Json::obj();
        for (l, v) in layers {
            obj.set(&l, Json::Arr(v));
        }
        root.set("layers", obj);
        root
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::cnn::tests::fake_resnet_bundle;
    use crate::nn::cnn::CnnModel;

    fn level(s: f64) -> Level {
        Level { sparsity: s, ..Level::dense() }
    }

    #[test]
    fn insert_get_levels() {
        let mut db = ModelDb::new("m");
        db.insert(Entry::from_mat("a", level(0.5), &Mat::zeros(2, 2), 1.0));
        db.insert(Entry::from_mat("a", level(0.75), &Mat::zeros(2, 2), 3.0));
        db.insert(Entry::from_mat("b", level(0.5), &Mat::zeros(2, 2), 0.5));
        assert_eq!(db.len(), 3);
        let ls = db.levels_for("a");
        assert_eq!(ls.len(), 2);
        assert!(db.get("a", &level(0.75)).is_some());
        assert!(db.get("a", &level(0.9)).is_none());
    }

    #[test]
    fn stitch_writes_layers() {
        let dense = CnnModel::resnet("rneta", &fake_resnet_bundle(1)).unwrap();
        let mut db = ModelDb::new("rneta");
        let name = "s0.b0.conv1";
        let w0 = dense.get_weight(name);
        db.insert(Entry::from_mat(name, level(1.0), &Mat::zeros(w0.rows, w0.cols), 9.0));
        let stitched = db.stitch(&dense, &[(name.to_string(), level(1.0))]);
        assert!(stitched.get_weight(name).data.iter().all(|&v| v == 0.0));
        // Dense model untouched.
        assert!(dense.get_weight(name).data.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn summary_json_roundtrips() {
        let mut db = ModelDb::new("m");
        db.insert(Entry::from_mat("a", level(0.5), &Mat::zeros(1, 1), 2.0));
        let s = db.summary_json().to_string_pretty();
        let back = crate::util::json::parse(&s).unwrap();
        assert_eq!(back.req_str("model").unwrap(), "m");
    }
}
