//! Cost models: FLOPs, BOPs (bit operations) and the DeepSparse-style CPU
//! latency model used by the paper's constrained-compression experiments.
//!
//! * FLOPs — 2 × MACs × density (unstructured/N:M/block sparsity scales
//!   compute linearly in the paper's accounting).
//! * BOPs — MACs × w_bits × a_bits, halved under 2:4 (the paper's Fig. 2
//!   x-axis: "BOP (number of bits times FLOPs) reduction").
//! * CPU latency — an analytical stand-in for the paper's measured
//!   DeepSparse layer timings: dense-int8 ≈ 2.7× over fp32; block-sparse
//!   speedup acts multiplicatively with a memory-bound floor, calibrated
//!   to the paper's statement that "sparsity speedup acts roughly
//!   multiplicatively" on top of the int8 base.

use crate::nn::LayerInfo;

/// Compression level of one layer, as stored in the model database.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Level {
    /// Fraction of zero weights (0 = dense).
    pub sparsity: f64,
    /// Weight bits (32 = uncompressed float).
    pub w_bits: u32,
    /// Activation bits.
    pub a_bits: u32,
    /// Semi-structured 2:4 pattern (GPU scenario).
    pub is_24: bool,
}

impl Level {
    /// The uncompressed reference. BOP accounting uses **fp16** as the
    /// dense precision (the standard GPU inference dtype): with an fp32
    /// base, uniform 8w8a alone would already be a 16× BOP reduction and
    /// the paper's 4–14× sweep range would be trivially flat.
    pub fn dense() -> Level {
        Level { sparsity: 0.0, w_bits: 16, a_bits: 16, is_24: false }
    }

    /// Stable database key, e.g. "s0.500_w4a4_24".
    pub fn key(&self) -> String {
        format!(
            "s{:.3}_w{}a{}{}",
            self.sparsity,
            self.w_bits,
            self.a_bits,
            if self.is_24 { "_24" } else { "" }
        )
    }
}

/// FLOPs of a layer at a given level (2 ops per MAC).
pub fn layer_flops(l: &LayerInfo, level: &Level) -> f64 {
    let density = if level.is_24 { 0.5 } else { 1.0 - level.sparsity };
    2.0 * l.macs as f64 * density
}

/// BOPs of a layer at a given level.
pub fn layer_bops(l: &LayerInfo, level: &Level) -> f64 {
    let density = if level.is_24 { 0.5 } else { 1.0 - level.sparsity };
    l.macs as f64 * density * level.w_bits as f64 * level.a_bits as f64
}

/// DeepSparse-like per-layer CPU latency model (arbitrary time units:
/// 1.0 == one fp32 dense MAC). See module docs; the α knob expresses how
/// much of the kernel is compute-bound (sparsity only accelerates that
/// part); small layers saturate at a memory-bound floor.
pub fn layer_cpu_time(l: &LayerInfo, sparsity: f64, int8: bool) -> f64 {
    let base = l.macs as f64;
    let quant_speedup = if int8 { 2.7 } else { 1.0 };
    let alpha = 0.85;
    let dense_t = base / quant_speedup;
    let sparse_t = dense_t * ((1.0 - alpha) + alpha * (1.0 - sparsity));
    // Memory-bound floor: reading the (compressed) weights.
    let floor = (l.weights() as f64) * (1.0 - sparsity) * 0.05 / quant_speedup;
    sparse_t.max(floor)
}

/// Total model cost at an assignment of levels (same order as `layers`).
pub fn total_flops(layers: &[LayerInfo], levels: &[Level]) -> f64 {
    layers.iter().zip(levels).map(|(l, v)| layer_flops(l, v)).sum()
}

pub fn total_bops(layers: &[LayerInfo], levels: &[Level]) -> f64 {
    layers.iter().zip(levels).map(|(l, v)| layer_bops(l, v)).sum()
}

pub fn total_cpu_time(layers: &[LayerInfo], levels: &[Level]) -> f64 {
    layers
        .iter()
        .zip(levels)
        .map(|(l, v)| layer_cpu_time(l, v.sparsity, v.w_bits <= 8))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(macs: u64, dr: usize, dc: usize) -> LayerInfo {
        LayerInfo { name: "t".into(), d_row: dr, d_col: dc, macs, kind: "conv" }
    }

    #[test]
    fn flops_scale_with_sparsity() {
        let l = layer(1000, 10, 10);
        assert_eq!(layer_flops(&l, &Level::dense()), 2000.0);
        assert_eq!(
            layer_flops(&l, &Level { sparsity: 0.5, ..Level::dense() }),
            1000.0
        );
    }

    #[test]
    fn bops_24_plus_4bit() {
        let l = layer(1000, 10, 10);
        let lv = Level { sparsity: 0.0, w_bits: 4, a_bits: 4, is_24: true };
        assert_eq!(layer_bops(&l, &lv), 8000.0);
        // vs the fp16 dense reference: 256/16 × 2 (2:4) = 32×.
        let reduction = layer_bops(&l, &Level::dense()) / layer_bops(&l, &lv);
        assert_eq!(reduction, 32.0);
    }

    #[test]
    fn cpu_time_int8_base_speedup() {
        let l = layer(1_000_000, 100, 100);
        let fp = layer_cpu_time(&l, 0.0, false);
        let q = layer_cpu_time(&l, 0.0, true);
        assert!((fp / q - 2.7).abs() < 1e-9);
    }

    #[test]
    fn cpu_time_monotone_in_sparsity() {
        let l = layer(1_000_000, 100, 100);
        let mut prev = f64::INFINITY;
        for s in [0.0, 0.3, 0.6, 0.9] {
            let t = layer_cpu_time(&l, s, true);
            assert!(t <= prev);
            prev = t;
        }
        assert!(layer_cpu_time(&l, 0.99, true) > 0.0);
    }

    #[test]
    fn level_key_stable() {
        let lv = Level { sparsity: 0.5, w_bits: 4, a_bits: 8, is_24: true };
        assert_eq!(lv.key(), "s0.500_w4a8_24");
    }
}
