//! Task metrics, matching `python/compile/train.py::metric_fn` exactly so
//! Rust-measured accuracies are comparable to the dense reference the
//! build-time trainer records.

use crate::nn::models::{batch_slice, task_of, ModelBundle};
use crate::nn::CompressibleModel;
use crate::tensor::Tensor;

/// Top-1 accuracy (%) for classification logits [N, C] vs labels [N].
pub fn top1(logits: &Tensor, labels: &Tensor) -> f64 {
    let preds = logits.argmax_last();
    let n = preds.len();
    let correct = preds
        .iter()
        .enumerate()
        .filter(|(i, &p)| p == labels.data[*i] as usize)
        .count();
    100.0 * correct as f64 / n as f64
}

/// Span F1 (%) for span logits [N, S, 2] vs gold spans [N, 2].
pub fn span_f1(logits: &Tensor, spans: &Tensor) -> f64 {
    let (n, s) = (logits.shape[0], logits.shape[1]);
    let mut total = 0.0;
    for i in 0..n {
        let (mut bs, mut be) = (0usize, 0usize);
        let (mut vs, mut ve) = (f32::NEG_INFINITY, f32::NEG_INFINITY);
        for j in 0..s {
            let sl = logits.at3(i, j, 0);
            let el = logits.at3(i, j, 1);
            if sl > vs {
                vs = sl;
                bs = j;
            }
            if el > ve {
                ve = el;
                be = j;
            }
        }
        let (a0, a1) = if be < bs { (be, bs) } else { (bs, be) };
        let g0 = spans.data[i * 2] as usize;
        let g1 = spans.data[i * 2 + 1] as usize;
        let inter = overlap(a0, a1, g0, g1);
        if inter > 0 {
            let prec = inter as f64 / (a1 - a0 + 1) as f64;
            let rec = inter as f64 / (g1 - g0 + 1) as f64;
            total += 2.0 * prec * rec / (prec + rec);
        }
    }
    100.0 * total / n as f64
}

fn overlap(a0: usize, a1: usize, b0: usize, b1: usize) -> usize {
    let lo = a0.max(b0);
    let hi = a1.min(b1);
    if hi >= lo {
        hi - lo + 1
    } else {
        0
    }
}

/// Detection cell-F1 (%) for logits [N, 1+C, G, G] vs grids [N, G, G]
/// (0 = background). Mirrors the python metric: TP = correct class on an
/// object cell; FP = any non-background prediction that is wrong; FN =
/// object predicted background.
pub fn det_f1(logits: &Tensor, grid: &Tensor) -> f64 {
    let (n, ch, g, _) = (logits.shape[0], logits.shape[1], logits.shape[2], logits.shape[3]);
    let (mut tp, mut fp, mut fnn) = (0f64, 0f64, 0f64);
    for i in 0..n {
        for y in 0..g {
            for x in 0..g {
                let mut best = 0usize;
                let mut bv = f32::NEG_INFINITY;
                for c in 0..ch {
                    let v = logits.at4(i, c, y, x);
                    if v > bv {
                        bv = v;
                        best = c;
                    }
                }
                let truth = grid.data[(i * g + y) * g + x] as usize;
                if truth > 0 {
                    if best == truth {
                        tp += 1.0;
                    } else {
                        fnn += if best == 0 { 1.0 } else { 0.0 };
                        fp += if best > 0 { 1.0 } else { 0.0 };
                    }
                } else if best > 0 {
                    fp += 1.0;
                }
            }
        }
    }
    let prec = tp / (tp + fp).max(1e-9);
    let rec = tp / (tp + fnn).max(1e-9);
    200.0 * prec * rec / (prec + rec).max(1e-9)
}

/// Evaluate a model on (x, y) for its task, batched to bound memory.
pub fn evaluate(model: &dyn CompressibleModel, x: &Tensor, y: &Tensor, batch: usize) -> f64 {
    let n = x.shape[0];
    let task = task_of(model.name());
    let mut weighted = 0.0;
    let mut i = 0;
    while i < n {
        let j = (i + batch).min(n);
        let xb = batch_slice(x, i, j);
        let yb = batch_slice(y, i, j);
        let logits = model.forward(&xb);
        let m = match task {
            "image" => top1(&logits, &yb),
            "seq" => span_f1(&logits, &yb),
            "det" => det_f1(&logits, &yb),
            _ => unreachable!(),
        };
        weighted += m * (j - i) as f64;
        i = j;
    }
    weighted / n as f64
}

/// Evaluate on the bundle's test split (optionally subsampled to
/// `max_samples` for cheap sweeps).
pub fn evaluate_bundle(b: &ModelBundle, model: &dyn CompressibleModel, max_samples: usize) -> f64 {
    let n = b.test_x.shape[0].min(max_samples);
    let x = batch_slice(&b.test_x, 0, n);
    let y = batch_slice(&b.test_y, 0, n);
    evaluate(model, &x, &y, 128)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top1_counts() {
        let logits = Tensor::from_vec(&[2, 3], vec![1.0, 5.0, 0.0, 9.0, 0.0, 0.0]);
        let labels = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        assert_eq!(top1(&logits, &labels), 50.0);
    }

    #[test]
    fn span_f1_exact_match_and_miss() {
        // N=2, S=4. First: predict [1,2] gold [1,2] → F1 1. Second:
        // predict [0,0] gold [2,3] → 0.
        let mut logits = Tensor::zeros(&[2, 4, 2]);
        logits.data[1 * 2] = 5.0; // i=0 j=1 start
        logits.data[2 * 2 + 1] = 5.0; // i=0 j=2 end
        logits.data[8] = 5.0; // i=1 j=0 start
        logits.data[8 + 1] = 5.0; // i=1 j=0 end
        let spans = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 2.0, 3.0]);
        assert_eq!(span_f1(&logits, &spans), 50.0);
    }

    #[test]
    fn span_f1_partial_overlap() {
        // Predict [0,1], gold [1,2]: inter 1, prec 0.5, rec 0.5, F1 0.5.
        let mut logits = Tensor::zeros(&[1, 4, 2]);
        logits.data[0] = 5.0; // start at 0
        logits.data[1 * 2 + 1] = 5.0; // end at 1
        let spans = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]);
        assert!((span_f1(&logits, &spans) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn det_f1_perfect() {
        // 1 image, 2 classes + bg, 1x1 grid with object class 1.
        let logits = Tensor::from_vec(&[1, 3, 1, 1], vec![0.0, 5.0, 0.0]);
        let grid = Tensor::from_vec(&[1, 1, 1], vec![1.0]);
        assert!((det_f1(&logits, &grid) - 100.0).abs() < 1e-9);
    }
}
