//! The acceptance criterion of the arena rework: after warmup, a row
//! sweep performs **zero** heap allocations — no H⁻¹ clone, no pivot-row
//! `to_vec`, no trace growth, nothing.
//!
//! Lives in its own test binary: the counting allocator's totals are
//! process-wide, so the measured region must be the only thing running.

use obc::compress::hessian::LayerHessian;
use obc::compress::quant::Grid;
use obc::compress::sweep;
use obc::linalg::{FMat, Mat};
use obc::util::alloc_counter::{self, CountingAlloc};
use obc::util::scratch::Scratch;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

// The counting allocator is process-wide: tests in this binary must not
// overlap, or each would see the other's allocations.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[test]
fn steady_state_sweeps_are_allocation_free() {
    let _serial = SERIAL.lock().unwrap();
    let d = 32;
    let w = Mat::randn(2, d, 950);
    let h = LayerHessian::from_inputs(&Mat::randn(d, d * 2 + 8, 951), 1e-8);
    let grid = Grid { scale: 0.125, zero: 16.0, maxq: 31.0 };
    let mut s = Scratch::new();

    // The layer-shared f32 narrowing is built once, outside the measured
    // region, exactly as the fan-outs do it.
    let h32 = FMat::from_mat(&h.hinv);

    // Warmup: grows every buffer the kernels will touch — including the
    // rank-B panel buffers (`ensure_batch`) and the mixed-tier f32
    // scratch panels (`ensure_mixed`).
    sweep::prune_sweep(&mut s, w.row(0), &h.hinv, d, |_, _| true).unwrap();
    sweep::quant_sweep(&mut s, w.row(0), &h.hinv, &grid, true).unwrap();
    sweep::prune_sweep_batched(&mut s, w.row(0), &h.hinv, d, 8, |_, _| true).unwrap();
    sweep::quant_sweep_batched(&mut s, w.row(0), &h.hinv, &grid, true, 8).unwrap();
    sweep::block_sweep(&mut s, w.row(0), &h.hinv, 4, 3);
    sweep::group_reconstruct(&mut s, w.row(0), &h.hinv, &[1, 4, 9, 17]).unwrap();
    sweep::prefix_reconstruct_multi(&mut s, w.row(0), &h.hinv, &[2, 7, 1, 12, 5], &[1, 3, 5], |_, _| {})
        .unwrap();
    sweep::prune_sweep_batched_mixed(&mut s, w.row(0), &h32, d, 8, |_, _| true).unwrap();
    sweep::quant_sweep_batched_mixed(&mut s, w.row(0), &h32, &grid, true, 8).unwrap();
    sweep::prefix_reconstruct_multi_mixed(
        &mut s,
        w.row(0),
        &h.hinv,
        &h32,
        &[2, 7, 1, 12, 5],
        &[1, 3, 5],
        |_, _| {},
    )
    .unwrap();

    let start = alloc_counter::snapshot();
    for _ in 0..5 {
        sweep::prune_sweep(&mut s, w.row(1), &h.hinv, d, |_, _| true).unwrap();
        sweep::quant_sweep(&mut s, w.row(1), &h.hinv, &grid, true).unwrap();
        // Rank-B lazy batching: panel staging, flush and live-list
        // compaction all reuse the warmed arena buffers.
        sweep::prune_sweep_batched(&mut s, w.row(1), &h.hinv, d, 8, |_, _| true).unwrap();
        sweep::quant_sweep_batched(&mut s, w.row(1), &h.hinv, &grid, true, 8).unwrap();
        sweep::block_sweep(&mut s, w.row(1), &h.hinv, 4, 3);
        sweep::group_reconstruct(&mut s, w.row(1), &h.hinv, &[0, 3, 11, 20]).unwrap();
        // The multi-level prefix reconstructor: factor extension, carried
        // forward solve and per-level output all live in the arena.
        sweep::prefix_reconstruct_multi(
            &mut s,
            w.row(1),
            &h.hinv,
            &[2, 7, 1, 12, 5],
            &[1, 3, 5],
            |k, row| {
                std::hint::black_box((k, row[0]));
            },
        )
        .unwrap();
        // The mixed tier holds the same zero-allocation contract: its
        // f32 working set lives in the warmed arena (`hinv32`/`panel32`)
        // and the shared narrowing is reused, never rebuilt.
        sweep::prune_sweep_batched_mixed(&mut s, w.row(1), &h32, d, 8, |_, _| true).unwrap();
        sweep::quant_sweep_batched_mixed(&mut s, w.row(1), &h32, &grid, true, 8).unwrap();
        sweep::prefix_reconstruct_multi_mixed(
            &mut s,
            w.row(1),
            &h.hinv,
            &h32,
            &[2, 7, 1, 12, 5],
            &[1, 3, 5],
            |k, row| {
                std::hint::black_box((k, row[0]));
            },
        )
        .unwrap();
    }
    let delta = alloc_counter::since(start);
    assert_eq!(
        delta.allocs, 0,
        "steady-state sweeps allocated {} times ({} bytes)",
        delta.allocs, delta.bytes
    );
}

/// The observability contract on the kernel hot path: a `span!` with no
/// collector installed is one thread-local flag read, and with a
/// collector armed it is two relaxed `fetch_add`s into a preallocated
/// profile — neither side allocates in steady state.
#[test]
fn spans_allocate_nothing_on_the_sweep_hot_path() {
    use obc::util::trace;
    use std::sync::Arc;

    let _serial = SERIAL.lock().unwrap();
    let d = 32;
    let w = Mat::randn(2, d, 960);
    let h = LayerHessian::from_inputs(&Mat::randn(d, d * 2 + 8, 961), 1e-8);
    let mut s = Scratch::new();
    // Warmup grows the arena; spans fire inside `batch_flush` on every
    // call below.
    sweep::prune_sweep_batched(&mut s, w.row(0), &h.hinv, d, 8, |_, _| true).unwrap();

    // Collector absent (the library default).
    let start = alloc_counter::snapshot();
    sweep::prune_sweep_batched(&mut s, w.row(1), &h.hinv, d, 8, |_, _| true).unwrap();
    let delta = alloc_counter::since(start);
    assert_eq!(delta.allocs, 0, "disabled spans must not allocate");

    // Collector armed: the profile is preallocated outside the measured
    // region; recording touches only its atomics.
    let profile = Arc::new(trace::Profile::new());
    let guard = trace::set(Some(Arc::clone(&profile)));
    let start = alloc_counter::snapshot();
    sweep::prune_sweep_batched(&mut s, w.row(1), &h.hinv, d, 8, |_, _| true).unwrap();
    let delta = alloc_counter::since(start);
    assert_eq!(delta.allocs, 0, "armed spans must not allocate");
    drop(guard);
    let flush_ns: u64 = profile
        .phases()
        .iter()
        .filter(|(name, _, _)| *name == "sweep.flush")
        .map(|(_, ns, _)| *ns)
        .sum();
    let flush_calls: u64 = profile
        .phases()
        .iter()
        .filter(|(name, _, _)| *name == "sweep.flush")
        .map(|(_, _, c)| *c)
        .sum();
    assert!(flush_calls >= 1, "the armed sweep must have recorded flush spans");
    assert!(flush_ns > 0 || flush_calls > 0);
}
