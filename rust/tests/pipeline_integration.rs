//! Integration: the full pipeline on a real trained model (skips until
//! `make artifacts` has produced rneta), plus a synthetic-model smoke
//! path that runs in every build mode — debug included — so tier-1
//! verify always exercises calibrate → compress → stitch → evaluate.

use obc::coordinator::methods::{PruneMethod, QuantMethod};
use obc::coordinator::pipeline::{LayerScope, Pipeline};
use obc::coordinator::{calibrate, CalibOpts};
use obc::solver::sparsity_grid;

fn pipeline_or_skip() -> Option<Pipeline> {
    if cfg!(debug_assertions) {
        // Full-model calibration + evaluation on *trained* artifacts is
        // only practical in release mode on this single-core testbed —
        // run `cargo test --release -q` to exercise these (plain
        // `cargo test` compiles in debug). Debug builds run
        // `debug_smoke_tiny_pipeline` below instead, so tier-1 verify
        // still covers the pipeline end to end.
        eprintln!("SKIP trained-model pipeline integration in debug build (use --release)");
        return None;
    }
    Pipeline::try_load_for_bench("rneta")
}

/// Debug-mode smoke path: a tiny synthetic model (no artifacts needed),
/// two compressed layers, end-to-end through calibration, ExactOBS
/// pruning, stitching, statistics correction and evaluation.
#[test]
fn debug_smoke_tiny_pipeline() {
    let bundle = obc::nn::models::synthetic_bundle(1);
    let calib = CalibOpts { n_samples: 32, batch: 16, ..Default::default() };
    let hessians = calibrate(bundle.model.as_ref(), &bundle, &calib).expect("calibrate");
    let p = Pipeline::from_parts(bundle, hessians, calib, 32);
    let dense = p.dense_metric();
    assert!(dense.is_finite());
    // Compress just two inner layers (keeps the debug-mode smoke fast).
    let mut model = p.model().clone_box();
    for l in p.layers(LayerScope::SkipFirstLast).into_iter().take(2) {
        let w = p.model().get_weight(&l.name);
        let h = &p.hessians()[&l.name];
        let r = PruneMethod::ExactObs.prune(&w, h, 0.5);
        assert!(r.sq_err.is_finite() && r.sq_err >= 0.0);
        assert!((r.sparsity - 0.5).abs() < 0.02, "sparsity {}", r.sparsity);
        model.set_weight(&l.name, &r.w);
    }
    let metric = p.eval_corrected(model);
    assert!(metric.is_finite(), "corrected metric not finite");
}

#[test]
fn dense_model_is_accurate() {
    let Some(p) = pipeline_or_skip() else { return };
    let dense = p.dense_metric();
    assert!(dense > 70.0, "dense rneta should be well-trained, got {dense}");
}

#[test]
fn moderate_pruning_keeps_most_accuracy_and_methods_order() {
    let Some(p) = pipeline_or_skip() else { return };
    let dense = p.dense_metric();
    let ex = p.run_uniform_sparsity(PruneMethod::ExactObs, 0.6, LayerScope::All);
    let gmp = p.run_uniform_sparsity(PruneMethod::Gmp, 0.6, LayerScope::All);
    assert!(ex > dense - 12.0, "ExactOBS @60% collapsed: {ex} vs dense {dense}");
    assert!(
        ex >= gmp - 1.0,
        "ExactOBS ({ex}) should not lose to GMP ({gmp}) at 60%"
    );
}

#[test]
fn nm_24_pattern_end_to_end() {
    let Some(p) = pipeline_or_skip() else { return };
    let dense = p.dense_metric();
    let m = p.run_nm(PruneMethod::ExactObs, 2, 4, LayerScope::SkipFirstLast);
    assert!(m > dense - 12.0, "2:4 collapsed: {m} vs dense {dense}");
}

#[test]
fn quant_4bit_close_to_dense() {
    let Some(p) = pipeline_or_skip() else { return };
    let dense = p.dense_metric();
    let m = p.run_quant(QuantMethod::Obq, 4, false, LayerScope::All, true);
    assert!(m > dense - 6.0, "4-bit OBQ too lossy: {m} vs dense {dense}");
    // Bits ordering: 4 ≥ 2 (allowing small noise).
    let m2 = p.run_quant(QuantMethod::Obq, 2, false, LayerScope::All, true);
    assert!(m + 1.0 >= m2, "2-bit ({m2}) beat 4-bit ({m})?");
}

#[test]
fn flop_target_pipeline_achieves_reduction() {
    let Some(p) = pipeline_or_skip() else { return };
    let grid = sparsity_grid(0.2, 0.92); // coarse grid for test speed
    let db = p.build_sparsity_db(PruneMethod::ExactObs, &grid, LayerScope::All);
    let (metric, achieved) = p
        .eval_flop_target(&db, LayerScope::All, 2.0)
        .expect("2x must be feasible");
    assert!(achieved >= 1.95, "achieved only {achieved}x");
    let dense = p.dense_metric();
    assert!(metric > dense - 15.0, "2x pruned collapsed: {metric} vs {dense}");
}

#[test]
fn bn_reset_recovers_accuracy() {
    // Statistics correction must help (that is why the paper applies it).
    let Some(p) = pipeline_or_skip() else { return };
    let mut model = p.model().clone_box();
    for l in p.layers(LayerScope::SkipFirstLast) {
        let w = p.model().get_weight(&l.name);
        let h = &p.hessians()[&l.name];
        let r = PruneMethod::ExactObs.prune(&w, h, 0.7);
        model.set_weight(&l.name, &r.w);
    }
    let raw = p.eval_raw(model.clone_box());
    let corrected = p.eval_corrected(model);
    assert!(
        corrected >= raw - 0.5,
        "BN reset hurt: raw {raw} corrected {corrected}"
    );
}
