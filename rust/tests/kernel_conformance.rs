//! Cross-kernel conformance: the native Rust kernels must reproduce the
//! golden cases generated from the Python oracle layer
//! (`python/compile/kernels/ref.py`, mirrored in f64 by
//! `python/compile/gen_fixtures.py` — regenerate with
//! `python3 python/compile/gen_fixtures.py`).
//!
//! This is the contract every backend is held to: the pytest suite pins
//! the Pallas kernels to the same oracle, and the PJRT path
//! (`--features pjrt`) is cross-checked against the native kernels by
//! `runtime_bridge.rs` — so all three implementations meet at these
//! fixtures. Weights must agree within 1e-6, pruning orders (masks) and
//! grids exactly.

use obc::compress::exact_obs;
use obc::compress::obq::{self, ObqOpts};
use obc::compress::quant::{Grid, GridSearch};
use obc::compress::sweep;
use obc::linalg::Mat;
use obc::util::json::{parse, Json};
use obc::util::scratch::Scratch;

fn load_fixture(name: &str) -> Json {
    let path = format!("{}/rust/tests/fixtures/{name}", env!("CARGO_MANIFEST_DIR"));
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read fixture {path}: {e}"));
    parse(&text).unwrap_or_else(|e| panic!("parse fixture {path}: {e}"))
}

fn f64_vec(j: &Json) -> Vec<f64> {
    j.as_arr()
        .expect("array")
        .iter()
        .map(|v| v.as_f64().expect("number"))
        .collect()
}

fn usize_vec(j: &Json) -> Vec<usize> {
    j.as_arr()
        .expect("array")
        .iter()
        .map(|v| v.as_usize().expect("index"))
        .collect()
}

fn mat_from(j: &Json, rows: usize, cols: usize) -> Mat {
    Mat::from_vec(rows, cols, f64_vec(j))
}

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + b.abs())
}

#[test]
fn obs_sweep_matches_python_golden_cases() {
    let fixture = load_fixture("obs_cases.json");
    let cases = fixture.get("cases").and_then(Json::as_arr).expect("cases");
    assert!(!cases.is_empty());
    for case in cases {
        let name = case.req_str("name").unwrap();
        let d = case.get("d").and_then(Json::as_usize).unwrap();
        let rows = case.get("rows").and_then(Json::as_usize).unwrap();
        let k = case.get("k").and_then(Json::as_usize).unwrap();
        let w = mat_from(case.get("w").unwrap(), rows, d);
        let hinv = mat_from(case.get("hinv").unwrap(), d, d);
        let expects = case.get("expect").and_then(Json::as_arr).unwrap();
        for r in 0..rows {
            let mut wr = w.row(r).to_vec();
            let mut h = hinv.clone();
            let trace = exact_obs::sweep_row(&mut wr, &mut h, k, |_, _| true);
            let exp = &expects[r];
            // Identical pruning order == identical mask.
            let want_order = usize_vec(exp.get("order").unwrap());
            assert_eq!(trace.order, want_order, "{name} row {r}: pruning order");
            let want_w = f64_vec(exp.get("w").unwrap());
            for c in 0..d {
                assert!(
                    close(wr[c], want_w[c], 1e-6),
                    "{name} row {r} col {c}: {} vs golden {}",
                    wr[c],
                    want_w[c]
                );
            }
            let want_dloss = f64_vec(exp.get("dloss").unwrap());
            assert_eq!(trace.dloss.len(), want_dloss.len(), "{name} row {r}: trace len");
            for (i, (a, b)) in trace.dloss.iter().zip(&want_dloss).enumerate() {
                assert!(*a >= 0.0, "{name} row {r} step {i}: negative dloss {a}");
                assert!(
                    close(*a, *b, 1e-6),
                    "{name} row {r} step {i}: dloss {a} vs golden {b}"
                );
            }
        }
    }
}

#[test]
fn obq_sweep_matches_python_golden_cases() {
    let fixture = load_fixture("obq_cases.json");
    let cases = fixture.get("cases").and_then(Json::as_arr).expect("cases");
    assert!(!cases.is_empty());
    for case in cases {
        let name = case.req_str("name").unwrap();
        let d = case.get("d").and_then(Json::as_usize).unwrap();
        let rows = case.get("rows").and_then(Json::as_usize).unwrap();
        let outlier = case.get("outlier").and_then(Json::as_bool).unwrap();
        let w = mat_from(case.get("w").unwrap(), rows, d);
        let hinv = mat_from(case.get("hinv").unwrap(), d, d);
        let grids_j = case.get("grids").and_then(Json::as_arr).unwrap();
        let expects = case.get("expect").and_then(Json::as_arr).unwrap();
        let opts = ObqOpts {
            bits: 4, // unused by quantize_row (grid is explicit)
            symmetric: false,
            search: GridSearch::MinMax,
            outlier_heuristic: outlier,
            batch: 1,
            precision: obc::util::precision::Precision::F64,
        };
        for r in 0..rows {
            let grid = Grid {
                scale: grids_j[r].req_f64("scale").unwrap(),
                zero: grids_j[r].req_f64("zero").unwrap(),
                maxq: grids_j[r].req_f64("maxq").unwrap(),
            };
            let got = obq::quantize_row(w.row(r), &hinv, &grid, &opts);
            let want = f64_vec(&expects[r]);
            for c in 0..d {
                // Weights within 1e-6, and every output on the *golden
                // grid* (identical grids by construction).
                assert!(
                    close(got[c], want[c], 1e-6),
                    "{name} row {r} col {c}: {} vs golden {}",
                    got[c],
                    want[c]
                );
                assert!(
                    (got[c] - grid.quant(got[c])).abs() < 1e-9,
                    "{name} row {r} col {c}: {} off grid",
                    got[c]
                );
            }
        }
    }
}

/// The rank-B lazy-batch prune engine against the same Python golden
/// fixtures: for every batch size — including B = d, a single flush for
/// the entire sweep — the elimination **order** must equal the golden
/// order exactly (batching reorders arithmetic, not selection), and the
/// compensated weights stay within the fixtures' 1e-6 contract.
#[test]
fn rank_b_obs_sweep_matches_golden_cases() {
    let fixture = load_fixture("obs_cases.json");
    let cases = fixture.get("cases").and_then(Json::as_arr).expect("cases");
    assert!(!cases.is_empty());
    let mut s = Scratch::new();
    for case in cases {
        let name = case.req_str("name").unwrap();
        let d = case.get("d").and_then(Json::as_usize).unwrap();
        let rows = case.get("rows").and_then(Json::as_usize).unwrap();
        let k = case.get("k").and_then(Json::as_usize).unwrap();
        let w = mat_from(case.get("w").unwrap(), rows, d);
        let hinv = mat_from(case.get("hinv").unwrap(), d, d);
        let expects = case.get("expect").and_then(Json::as_arr).unwrap();
        for r in 0..rows {
            let exp = &expects[r];
            let want_order = usize_vec(exp.get("order").unwrap());
            let want_w = f64_vec(exp.get("w").unwrap());
            for batch in [2usize, 8, d] {
                sweep::prune_sweep_batched(&mut s, w.row(r), &hinv, k, batch, |_, _| true)
                    .unwrap_or_else(|e| panic!("{name} row {r} B={batch}: {e:?}"));
                assert_eq!(
                    s.trace_order, want_order,
                    "{name} row {r} B={batch}: pruning order"
                );
                let out = s.out();
                for c in 0..d {
                    assert!(
                        close(out[c], want_w[c], 1e-6),
                        "{name} row {r} col {c} B={batch}: {} vs golden {}",
                        out[c],
                        want_w[c]
                    );
                }
            }
        }
    }
}

/// Rank-B OBQ sweeps against the golden quantization fixtures: outputs
/// within 1e-6 of golden and exactly on the golden grid for every batch
/// size.
#[test]
fn rank_b_obq_sweep_matches_golden_cases() {
    let fixture = load_fixture("obq_cases.json");
    let cases = fixture.get("cases").and_then(Json::as_arr).expect("cases");
    assert!(!cases.is_empty());
    let mut s = Scratch::new();
    for case in cases {
        let name = case.req_str("name").unwrap();
        let d = case.get("d").and_then(Json::as_usize).unwrap();
        let rows = case.get("rows").and_then(Json::as_usize).unwrap();
        let outlier = case.get("outlier").and_then(Json::as_bool).unwrap();
        let w = mat_from(case.get("w").unwrap(), rows, d);
        let hinv = mat_from(case.get("hinv").unwrap(), d, d);
        let grids_j = case.get("grids").and_then(Json::as_arr).unwrap();
        let expects = case.get("expect").and_then(Json::as_arr).unwrap();
        for r in 0..rows {
            let grid = Grid {
                scale: grids_j[r].req_f64("scale").unwrap(),
                zero: grids_j[r].req_f64("zero").unwrap(),
                maxq: grids_j[r].req_f64("maxq").unwrap(),
            };
            let want = f64_vec(&expects[r]);
            for batch in [2usize, 8, d] {
                sweep::quant_sweep_batched(&mut s, w.row(r), &hinv, &grid, outlier, batch)
                    .unwrap_or_else(|e| panic!("{name} row {r} B={batch}: {e:?}"));
                let got = s.out();
                for c in 0..d {
                    assert!(
                        close(got[c], want[c], 1e-6),
                        "{name} row {r} col {c} B={batch}: {} vs golden {}",
                        got[c],
                        want[c]
                    );
                    assert!(
                        (got[c] - grid.quant(got[c])).abs() < 1e-9,
                        "{name} row {r} col {c} B={batch}: {} off grid",
                        got[c]
                    );
                }
            }
        }
    }
}

#[test]
fn hessian_matches_python_golden_cases() {
    let fixture = load_fixture("hessian_cases.json");
    let cases = fixture.get("cases").and_then(Json::as_arr).expect("cases");
    assert!(!cases.is_empty());
    for case in cases {
        let name = case.req_str("name").unwrap();
        let d = case.get("d").and_then(Json::as_usize).unwrap();
        let n = case.get("n").and_then(Json::as_usize).unwrap();
        let x = mat_from(case.get("x").unwrap(), d, n);
        let want = mat_from(case.get("h").unwrap(), d, d);
        let mut acc = obc::compress::hessian::HessianAccumulator::new(d);
        acc.add_batch(&x);
        let got = acc.raw();
        // Different summation orders (numpy BLAS vs the in-tree xxt), so
        // tolerance-based: 1e-9 relative is ~1000x looser than the
        // observed drift and ~1000x tighter than the 1e-6 contract.
        for i in 0..d * d {
            assert!(
                close(got.data[i], want.data[i], 1e-9),
                "{name} elem {i}: {} vs golden {}",
                got.data[i],
                want.data[i]
            );
        }
    }
}
