//! The incremental trace-prefix database builder's contract:
//! **bit-identical** to the per-level reference path on every grid shape
//! — unstructured and block grids, any pool size, dirty arena reuse
//! across consecutive layers of different dimensions — at a fraction of
//! the selection + reconstruction cost (timed by `benches/db_build.rs`).

use obc::compress::exact_obs::{self, ObsOpts};
use obc::compress::hessian::LayerHessian;
use obc::compress::trace_db;
use obc::coordinator::engine::{CompressionEngine, LayerScope};
use obc::coordinator::methods::PruneMethod;
use obc::linalg::Mat;
use obc::util::pool::ThreadPool;
use obc::util::proptest as pt;

fn setup(d_row: usize, d_col: usize, seed: u64) -> (Mat, LayerHessian) {
    let w = Mat::randn(d_row, d_col, seed);
    let x = Mat::randn(d_col, d_col * 2 + 8, seed + 7000);
    (w, LayerHessian::from_inputs(&x, 1e-8))
}

/// Randomized unstructured grids: the one-pass multi-level selection +
/// factor-extension reconstruction must equal the per-level reference
/// (independent `global_select` + `reconstruct_from_traces_on` per
/// level) to the last ulp — weights, error, sparsity — on every level,
/// for every pool size, with worker arenas left dirty by previous cases
/// of other shapes.
#[test]
fn incremental_unstructured_levels_bit_identical_to_reference() {
    let pools = [ThreadPool::new(1), ThreadPool::new(2), ThreadPool::new(4)];
    pt::check(0xdb1c4e, 12, |g| {
        let d_row = g.usize_in(1, 6);
        let d = g.usize_in(8, 24);
        let (w, h) = setup(d_row, d, g.rng.next_u64());
        let pool = &pools[g.usize_in(0, pools.len() - 1)];
        let cap = if g.bool() { 1.0 } else { 0.8 };
        let traces = exact_obs::sweep_all_rows_on(
            pool,
            &w,
            &h,
            &ObsOpts { trace_cap: cap, ..Default::default() },
        );
        // Random grid: unsorted levels, duplicates, extremes included.
        let total = d_row * d;
        let n_levels = g.usize_in(1, 7);
        let mut k_totals: Vec<usize> =
            (0..n_levels).map(|_| g.usize_in(0, total)).collect();
        if g.bool() {
            k_totals.push(k_totals[0]); // duplicate level
        }
        let counts = exact_obs::global_select_multi(&traces, &k_totals);
        for (l, &k) in k_totals.iter().enumerate() {
            if counts[l] != exact_obs::global_select(&traces, k) {
                return Err(format!("selection diverged at level {l} (k={k})"));
            }
        }
        let levels = trace_db::unstructured_levels_on(pool, &w, &h, &traces, &counts);
        for (l, res) in levels.iter().enumerate() {
            let reference =
                exact_obs::reconstruct_from_traces_on(pool, &w, &h, &traces, &counts[l]);
            if res.w.data != reference.w.data {
                return Err(format!(
                    "weights diverged at level {l} (d_row={d_row}, d={d}, k={})",
                    k_totals[l]
                ));
            }
            if res.sq_err.to_bits() != reference.sq_err.to_bits()
                || res.sparsity != reference.sparsity
            {
                return Err(format!("err/sparsity diverged at level {l}"));
            }
        }
        Ok(())
    });
}

/// Randomized block grids: block traces expand to weight prefixes; every
/// level must match a per-level group-OBS reconstruction of exactly the
/// expanded sets (the historical CPU-database inner loop).
#[test]
fn incremental_block_levels_bit_identical_to_reference() {
    let pools = [ThreadPool::new(1), ThreadPool::new(3)];
    pt::check(0xb10cdb, 10, |g| {
        let d_row = g.usize_in(1, 5);
        let c = if g.bool() { 2 } else { 4 };
        let d = g.usize_in(2, 6) * c + if g.bool() { 1 } else { 0 }; // tail weights too
        let (w, h) = setup(d_row, d, g.rng.next_u64());
        let pool = &pools[g.usize_in(0, pools.len() - 1)];
        let traces = exact_obs::sweep_all_rows_block_on(pool, &w, &h, c, 1.0);
        let max_blocks: usize = traces.iter().map(|t| t.order.len()).sum();
        let n_levels = g.usize_in(1, 5);
        let kb_totals: Vec<usize> =
            (0..n_levels).map(|_| g.usize_in(0, max_blocks)).collect();
        let counts = exact_obs::global_select_multi(&traces, &kb_totals);
        let levels = trace_db::block_levels_on(pool, &w, &h, &traces, c, &counts, true);
        for (l, res) in levels.iter().enumerate() {
            let mut out = w.clone();
            for r in 0..d_row {
                let kb = counts[l][r];
                if kb == 0 {
                    continue;
                }
                let mut pruned = Vec::with_capacity(kb * c);
                for &b in &traces[r].order[..kb] {
                    pruned.extend(b * c..((b + 1) * c).min(d));
                }
                let row = exact_obs::group_obs_reconstruct(w.row(r), &h.hinv, &pruned);
                out.row_mut(r).copy_from_slice(&row);
            }
            if res.w.data != out.data {
                return Err(format!(
                    "block weights diverged at level {l} (c={c}, d={d}, kb={})",
                    kb_totals[l]
                ));
            }
            let err = obc::compress::layer_sq_err(&w, &out, &h.h);
            if res.sq_err.to_bits() != err.to_bits() {
                return Err(format!("block err diverged at level {l}"));
            }
        }
        Ok(())
    });
}

fn assert_dbs_identical(
    a: &obc::db::ModelDb,
    b: &obc::db::ModelDb,
    layers: &[String],
    what: &str,
) {
    assert_eq!(a.len(), b.len(), "{what}: entry counts");
    let mut seen = 0usize;
    for layer in layers {
        let la = a.levels_for(layer);
        assert!(!la.is_empty(), "{what}: no levels for {layer}");
        for (level, sq_err) in la {
            let ea = a.get(layer, level).expect("entry listed by levels_for");
            assert_eq!(ea.sq_err, sq_err);
            let eb = b
                .get(layer, level)
                .unwrap_or_else(|| panic!("{what}: missing ({layer}, {})", level.key()));
            assert_eq!(ea.w, eb.w, "{what}: weights ({layer}, {})", level.key());
            assert_eq!(
                ea.sq_err.to_bits(),
                eb.sq_err.to_bits(),
                "{what}: sq_err ({layer}, {})",
                level.key()
            );
            seen += 1;
        }
    }
    assert_eq!(seen, a.len(), "{what}: every entry visited");
}

fn layer_names(e: &CompressionEngine, scope: LayerScope) -> Vec<String> {
    e.layers(scope).into_iter().map(|l| l.name).collect()
}

/// Engine-level acceptance: the production sparsity-database builder
/// (incremental, layer items fanned across the coarse tier) must be
/// bit-identical to the kept per-level reference path — every layer,
/// every Eq. 10 level, weights and losses.
#[test]
fn engine_sparsity_db_incremental_matches_reference() {
    let e = CompressionEngine::synthetic(7).unwrap();
    let grid = [0.0, 0.3, 0.5, 0.7, 0.9];
    let inc = e
        .build_sparsity_db(PruneMethod::ExactObs, &grid, LayerScope::All)
        .unwrap();
    let reference = e
        .reference_build_sparsity_db(PruneMethod::ExactObs, &grid, LayerScope::All)
        .unwrap();
    assert!(!inc.is_empty());
    assert_dbs_identical(&inc, &reference, &layer_names(&e, LayerScope::All), "sparsity db");
}

/// Same for the CPU database (block sparsity × int8): the incremental
/// pooled path must equal the historical serial per-row reference loop.
#[test]
fn engine_cpu_db_incremental_matches_reference() {
    let e = CompressionEngine::synthetic(9).unwrap();
    let grid = [0.0, 0.4, 0.8];
    let inc = e.build_cpu_db(&grid, LayerScope::All).unwrap();
    let reference = e.reference_build_cpu_db(&grid, LayerScope::All).unwrap();
    assert!(!inc.is_empty());
    assert_dbs_identical(&inc, &reference, &layer_names(&e, LayerScope::All), "cpu db");
}

/// Baseline methods keep their per-level behavior through the new layer
/// fan-out: entries identical to a serial reference build.
#[test]
fn engine_baseline_sparsity_db_unchanged_by_layer_fanout() {
    let e = CompressionEngine::synthetic(11).unwrap();
    let grid = [0.0, 0.5, 0.9];
    let inc = e.build_sparsity_db(PruneMethod::Gmp, &grid, LayerScope::All).unwrap();
    let reference =
        e.reference_build_sparsity_db(PruneMethod::Gmp, &grid, LayerScope::All).unwrap();
    assert_dbs_identical(&inc, &reference, &layer_names(&e, LayerScope::All), "gmp sparsity db");
}
