//! Fault-injection chaos harness for the serving stack.
//!
//! Every test arms a seeded fault plan (`util::faultpoint`) and drives
//! the real server — TCP or in-process — asserting the hardening
//! contracts end to end:
//!
//! * **exactly-once**: every accepted job gets exactly one response —
//!   a result or a typed rejection — under faults at every recoverable
//!   site, with successful responses **bit-identical** to a fault-free
//!   run (injected NonSpd re-runs unchanged, store faults fall back to
//!   bit-identical live builds, delays change nothing);
//! * **deadlines**: an expired budget is a typed `"rejected":"deadline"`
//!   response, enforced at dequeue and at per-layer checkpoints;
//! * **load shedding**: past the admission watermark, submissions get
//!   typed `"rejected":"overloaded"` responses while accepted jobs all
//!   complete;
//! * **degraded store**: a store whose saves keep failing flips to
//!   memory-only (`store_degraded` metric) and the server keeps
//!   answering every job;
//! * **drain hygiene**: a half-written line at shutdown and a client
//!   that disconnects with a response queued leave no wedged workers
//!   and exact counter accounting;
//! * **catalog coverage**: a zero-probability wildcard plan observes
//!   every site in [`faultpoint::CATALOG`] without firing, and the run
//!   stays bit-identical to faults-off.
//!
//! The fault registry is process-global: every test takes
//! [`faultpoint::test_guard`] first, serializing the suite.

use obc::server::net::serve_tcp;
use obc::server::registry::SYNTHETIC_MODEL;
use obc::server::{run_line_protocol, CompressionServer, Response, ServerConfig};
use obc::util::faultpoint;
use obc::util::json::Json;
use std::io::{BufRead, BufReader, Write as IoWrite};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

fn tmp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("obc_chaos_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn cfg() -> ServerConfig {
    ServerConfig {
        workers: 2,
        queue_cap: 32,
        models_dir: PathBuf::from("/nonexistent"),
        synthetic_only: true,
        ..ServerConfig::default()
    }
}

/// The mixed batch every client sends: dense, prune, quant, and a
/// db-backed solve (exercises build + store write-through when a store
/// is attached).
fn job_lines() -> Vec<String> {
    vec![
        r#"{"id":"d1","model":"synthetic","op":"dense"}"#.into(),
        r#"{"id":"p1","model":"synthetic","op":"prune","method":"exactobs","sparsity":0.5}"#
            .into(),
        r#"{"id":"q1","model":"synthetic","op":"quant","method":"obq","bits":4}"#.into(),
        r#"{"id":"s1","model":"synthetic","op":"solve","target":"flop","value":1.5,"grid":[0,0.5,0.9]}"#
            .into(),
    ]
}

/// Strip fields that legitimately differ across runs and schedules; the
/// payload that remains must be byte-identical (sorted keys, shortest
/// roundtrip floats — see `server_concurrency.rs`).
fn normalize(line: &str) -> String {
    match obc::util::json::parse(line).unwrap_or_else(|e| panic!("bad line {line}: {e}")) {
        Json::Obj(mut m) => {
            let volatile = ["seq", "queue_seconds", "seconds", "coalesced", "cached", "cached_db"];
            for key in volatile {
                m.remove(key);
            }
            Json::Obj(m).to_string_compact()
        }
        other => other.to_string_compact(),
    }
}

#[derive(Clone, Default)]
struct SharedBuf(Arc<Mutex<Vec<u8>>>);
impl std::io::Write for SharedBuf {
    fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(b);
        Ok(b.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Run `lines` + shutdown through the in-process stdin protocol and
/// return (normalized+sorted job responses, shutdown ack).
fn stdin_run(config: ServerConfig, lines: &[String]) -> (Vec<String>, Json) {
    let mut input = lines.join("\n");
    input.push_str("\n{\"op\":\"shutdown\"}\n");
    let buf = SharedBuf::default();
    run_line_protocol(config, input.as_bytes(), buf.clone()).unwrap();
    let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
    let mut jobs: Vec<String> =
        text.lines().filter(|l| l.contains("\"id\":")).map(normalize).collect();
    jobs.sort();
    let ack = obc::util::json::parse(text.lines().last().unwrap()).unwrap();
    assert_eq!(ack.get("op").and_then(|v| v.as_str()), Some("shutdown"), "{text}");
    (jobs, ack)
}

fn counter(j: &Json, key: &str) -> f64 {
    j.get(key)
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("missing counter {key}: {}", j.to_string_compact()))
}

/// Tentpole acceptance: concurrent TCP clients under seeded faults at
/// every *recoverable* site — every request answered exactly once, all
/// jobs succeed (these faults are survivable by design: store faults
/// fall back to live builds, the injected NonSpd re-runs unchanged,
/// delays are just delays), and the payloads are bit-identical to a
/// fault-free stdin run.
#[test]
fn seeded_faults_exactly_once_and_bit_identical() {
    let _g = faultpoint::test_guard();
    // Fault-free reference first (guard holds the plan clear).
    let (reference, _) = stdin_run(cfg(), &job_lines());
    assert_eq!(reference.len(), job_lines().len());

    faultpoint::install_from_spec(
        "store.*=err@0.4,sweep.redamp.nonspd=err@0.3,engine.layer=delay:1ms@0.3,queue.push=delay:1ms@0.3",
        0xC0FFEE,
    )
    .unwrap();

    let store_dir = tmp_dir("exactly_once");
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve_tcp(ServerConfig { store_dir: Some(store_dir), ..cfg() }, listener).unwrap()
    });

    let clients: Vec<_> = (0..4)
        .map(|c| {
            let lines = job_lines();
            std::thread::spawn(move || {
                let mut s = TcpStream::connect(addr).unwrap();
                s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
                for l in &lines {
                    writeln!(s, "{l}").unwrap();
                }
                s.flush().unwrap();
                let mut r = BufReader::new(s);
                let mut got = Vec::new();
                for i in 0..lines.len() {
                    let mut line = String::new();
                    r.read_line(&mut line).unwrap_or_else(|e| panic!("client {c} read: {e}"));
                    assert!(!line.is_empty(), "client {c}: closed before response {i}");
                    got.push(normalize(line.trim()));
                }
                got.sort();
                got
            })
        })
        .collect();
    for (c, h) in clients.into_iter().enumerate() {
        let got = h.join().unwrap();
        assert_eq!(got, reference, "client {c}: faulted run diverged from fault-free run");
    }
    assert!(faultpoint::total_fired() > 0, "the plan must actually inject faults");

    // Shutdown; the post-drain ack accounts for every accepted job.
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    writeln!(s, "{{\"op\":\"shutdown\"}}").unwrap();
    let mut ack_line = String::new();
    BufReader::new(s).read_line(&mut ack_line).unwrap();
    let ack = obc::util::json::parse(ack_line.trim()).unwrap();
    let submitted = counter(&ack, "jobs_submitted");
    assert_eq!(submitted, counter(&ack, "jobs_completed"), "{ack_line}");
    assert_eq!(counter(&ack, "jobs_failed"), 0.0, "{ack_line}");
    assert_eq!(submitted, 16.0, "4 clients x 4 jobs all accepted: {ack_line}");
    server.join().unwrap();
    faultpoint::clear();
}

/// Deadlines are typed rejections: enforced at per-layer execution
/// checkpoints (an injected delay burns the budget) while an identical
/// job without a deadline sails through the same delays.
#[test]
fn deadline_is_a_typed_rejection_at_layer_checkpoints() {
    let _g = faultpoint::test_guard();
    faultpoint::install_from_spec("engine.layer=delay:50ms@1", 1).unwrap();
    let lines = vec![
        r#"{"id":"late","model":"synthetic","op":"prune","method":"exactobs","sparsity":0.4,"deadline_ms":30}"#
            .to_string(),
        r#"{"id":"calm","model":"synthetic","op":"prune","method":"exactobs","sparsity":0.5}"#
            .to_string(),
    ];
    let (jobs, ack) = stdin_run(ServerConfig { workers: 1, ..cfg() }, &lines);
    assert_eq!(jobs.len(), 2, "both requests answered");
    let by_id = |id: &str| {
        jobs.iter()
            .map(|l| obc::util::json::parse(l).unwrap())
            .find(|j| j.get("id").and_then(|v| v.as_str()) == Some(id))
            .unwrap_or_else(|| panic!("no response for {id}: {jobs:?}"))
    };
    let late = by_id("late");
    assert_eq!(late.get("ok").and_then(|v| v.as_bool()), Some(false), "{jobs:?}");
    assert_eq!(late.get("rejected").and_then(|v| v.as_str()), Some("deadline"), "{jobs:?}");
    let msg = late.get("error").and_then(|v| v.as_str()).unwrap().to_string();
    assert!(msg.starts_with("deadline exceeded"), "pinned prefix: {msg}");
    let calm = by_id("calm");
    assert_eq!(calm.get("ok").and_then(|v| v.as_bool()), Some(true), "{jobs:?}");
    assert_eq!(counter(&ack, "jobs_deadline_expired"), 1.0);
    assert_eq!(counter(&ack, "jobs_completed"), 1.0);
    assert_eq!(counter(&ack, "jobs_failed"), 1.0, "deadline rejection counts as failed");
    faultpoint::clear();
}

/// A zero budget expires while queued: rejected at dequeue, before any
/// execution — db_builds/calibrations stay untouched.
#[test]
fn zero_deadline_rejected_at_dequeue_without_executing() {
    let _g = faultpoint::test_guard();
    let server = CompressionServer::start(ServerConfig { workers: 1, ..cfg() });
    let (tx, rx) = mpsc::channel();
    server
        .submit_with_deadline(
            SYNTHETIC_MODEL,
            obc::coordinator::jobs::JobSpec::Dense,
            Some("z".into()),
            Some(Duration::ZERO),
            tx,
        )
        .unwrap();
    let resp: Response = rx.recv().unwrap();
    let err = resp.outcome.unwrap_err();
    assert!(err.starts_with("deadline exceeded"), "{err}");
    assert!(err.contains("before execution"), "dequeue-time rejection: {err}");
    let m = server.metrics_json();
    assert_eq!(counter(&m, "jobs_deadline_expired"), 1.0);
    assert_eq!(counter(&m, "calibrations"), 0.0, "never reached the registry");
    server.shutdown();
}

/// Load shedding over TCP: a one-worker server with a depth-2 watermark
/// and slowed layers sheds most of a 16-job burst with typed
/// `overloaded` rejections; every accepted job completes and the
/// counters reconcile exactly.
#[test]
fn overload_sheds_typed_and_accepted_jobs_complete() {
    let _g = faultpoint::test_guard();
    faultpoint::install_from_spec("engine.layer=delay:20ms@1", 2).unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve_tcp(ServerConfig { workers: 1, shed_depth: Some(2), ..cfg() }, listener).unwrap()
    });

    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let n = 16;
    for i in 0..n {
        // Distinct sparsities: no coalescing, every job is real work.
        writeln!(
            s,
            "{{\"id\":\"j{i}\",\"model\":\"synthetic\",\"op\":\"prune\",\"method\":\"exactobs\",\"sparsity\":0.{:02}}}",
            30 + i
        )
        .unwrap();
    }
    s.flush().unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut ok = 0u64;
    let mut shed = 0u64;
    for i in 0..n {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "response {i} missing");
        let j = obc::util::json::parse(line.trim()).unwrap();
        if j.get("ok").unwrap().as_bool().unwrap() {
            ok += 1;
        } else {
            assert_eq!(
                j.get("rejected").and_then(|v| v.as_str()),
                Some("overloaded"),
                "only typed shedding expected: {line}"
            );
            let msg = j.get("error").and_then(|v| v.as_str()).unwrap();
            assert!(msg.contains("overloaded"), "{msg}");
            shed += 1;
        }
    }
    assert!(shed >= 1, "watermark 2 must shed under a {n}-job burst");
    assert!(ok >= 1, "accepted jobs must complete");
    assert_eq!(ok + shed, n as u64, "every request answered exactly once");

    writeln!(s, "{{\"op\":\"shutdown\"}}").unwrap();
    let mut ack_line = String::new();
    r.read_line(&mut ack_line).unwrap();
    let ack = obc::util::json::parse(ack_line.trim()).unwrap();
    assert_eq!(counter(&ack, "jobs_shed"), shed as f64, "{ack_line}");
    assert_eq!(counter(&ack, "jobs_submitted"), ok as f64, "{ack_line}");
    assert_eq!(counter(&ack, "jobs_completed"), ok as f64, "{ack_line}");
    assert_eq!(counter(&ack, "jobs_failed"), 0.0, "{ack_line}");
    server.join().unwrap();
    faultpoint::clear();
}

/// A store whose every save fails flips to memory-only after the
/// failure streak: `store_degraded` reports 1, saves become no-ops,
/// and every job is still answered successfully.
#[test]
fn failing_store_degrades_to_memory_only_and_keeps_serving() {
    let _g = faultpoint::test_guard();
    faultpoint::install_from_spec("store.save.write=err@1", 3).unwrap();
    let dir = tmp_dir("degrade");
    let server = CompressionServer::start(ServerConfig {
        workers: 1,
        store_dir: Some(dir.clone()),
        ..cfg()
    });
    let (tx, rx) = mpsc::channel();
    // Four distinct builds: each save fails (retries exhausted), the
    // third failure trips the degrade threshold.
    let grids: [&[f64]; 4] = [&[0.0, 0.5], &[0.0, 0.6], &[0.0, 0.7], &[0.0, 0.8]];
    for (i, g) in grids.iter().enumerate() {
        let spec = obc::coordinator::jobs::JobSpec::BuildDb(obc::coordinator::jobs::DbSpec {
            kind: obc::coordinator::jobs::DbKind::Sparsity,
            method: obc::coordinator::methods::PruneMethod::ExactObs,
            grid: g.to_vec(),
            scope: obc::coordinator::engine::LayerScope::All,
        });
        server.submit(SYNTHETIC_MODEL, spec, Some(format!("b{i}")), tx.clone()).unwrap();
    }
    drop(tx);
    let resps: Vec<Response> = rx.iter().collect();
    assert_eq!(resps.len(), grids.len(), "every job answered");
    for r in &resps {
        assert!(r.outcome.is_ok(), "save failures must not fail jobs: {:?}", r.outcome);
    }
    let m = server.metrics_json();
    assert_eq!(counter(&m, "store_degraded"), 1.0, "{}", m.to_string_compact());
    assert_eq!(counter(&m, "store_saves"), 0.0, "no save ever succeeded");
    assert_eq!(counter(&m, "db_builds"), grids.len() as f64);
    server.shutdown();
    faultpoint::clear();
}

/// Drain hygiene (satellite d): one client leaves a half-written line
/// in its buffer at shutdown, another disconnects while its response is
/// still queued — the drain stays clean, nothing wedges, and the ack
/// accounts for exactly the accepted jobs.
#[test]
fn half_written_line_and_vanished_client_drain_cleanly() {
    let _g = faultpoint::test_guard();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve_tcp(ServerConfig { workers: 1, ..cfg() }, listener).unwrap()
    });

    // Client A: one complete job, then a half-written line (no newline),
    // connection kept open across the shutdown.
    let mut a = TcpStream::connect(addr).unwrap();
    a.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    writeln!(
        a,
        "{{\"id\":\"a1\",\"model\":\"synthetic\",\"op\":\"prune\",\"method\":\"exactobs\",\"sparsity\":0.5}}"
    )
    .unwrap();
    write!(a, "{{\"id\":\"a2\",\"model\":\"synthetic\",\"op\":\"pr").unwrap(); // no '\n'
    a.flush().unwrap();

    // Client C: submits a job, then vanishes before its response.
    let mut c = TcpStream::connect(addr).unwrap();
    writeln!(
        c,
        "{{\"id\":\"c1\",\"model\":\"synthetic\",\"op\":\"quant\",\"method\":\"obq\",\"bits\":4}}"
    )
    .unwrap();
    c.flush().unwrap();
    std::thread::sleep(Duration::from_millis(200)); // let both readers ingest
    let _ = c.shutdown(std::net::Shutdown::Both);
    drop(c);

    // Client B pulls the plug.
    let mut b = TcpStream::connect(addr).unwrap();
    b.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    writeln!(b, "{{\"op\":\"shutdown\"}}").unwrap();

    // A gets exactly one response (a1); the half-written a2 is dropped
    // at the drain, never parsed, never answered with garbage.
    let mut ra = BufReader::new(a.try_clone().unwrap());
    let mut line = String::new();
    ra.read_line(&mut line).unwrap();
    let j = obc::util::json::parse(line.trim()).unwrap();
    assert_eq!(j.get("id").and_then(|v| v.as_str()), Some("a1"), "{line}");
    assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true), "{line}");
    let mut tail = String::new();
    while ra.read_line(&mut tail).unwrap_or(0) > 0 {}
    assert!(tail.trim().is_empty(), "no response for a half-written request: {tail:?}");

    // B's ack accounts for exactly the two accepted jobs — including
    // the one whose client vanished (its response write is abandoned,
    // its execution and accounting are not).
    let mut ack_line = String::new();
    BufReader::new(b).read_line(&mut ack_line).unwrap();
    let ack = obc::util::json::parse(ack_line.trim()).unwrap();
    assert_eq!(counter(&ack, "jobs_submitted"), 2.0, "{ack_line}");
    assert_eq!(counter(&ack, "jobs_completed"), 2.0, "{ack_line}");
    assert_eq!(counter(&ack, "jobs_failed"), 0.0, "{ack_line}");
    // No wedged workers/handlers: the accept loop itself wound down.
    server.join().unwrap();
}

/// Coverage: a zero-probability wildcard plan records every site in the
/// shipped catalog across a store-backed cold run + warm restart over
/// TCP — and, firing nothing, stays bit-identical to faults-off.
#[test]
fn zero_probability_plan_covers_catalog_without_firing() {
    let _g = faultpoint::test_guard();
    // Fault-free reference before arming.
    let (reference, _) = stdin_run(cfg(), &job_lines());

    faultpoint::install_from_spec("*=err@0", 1).unwrap();
    let dir = tmp_dir("coverage");
    let run_once = |phase: &str| -> Vec<String> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let store_dir = dir.clone();
        let server = std::thread::spawn(move || {
            serve_tcp(ServerConfig { store_dir: Some(store_dir), ..cfg() }, listener).unwrap()
        });
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        for l in job_lines() {
            writeln!(s, "{l}").unwrap();
        }
        s.flush().unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut got = Vec::new();
        for i in 0..job_lines().len() {
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            assert!(!line.is_empty(), "{phase}: response {i} missing");
            got.push(normalize(line.trim()));
        }
        writeln!(s, "{{\"op\":\"shutdown\"}}").unwrap();
        let mut ack = String::new();
        r.read_line(&mut ack).unwrap();
        server.join().unwrap();
        got.sort();
        got
    };

    // Cold run builds + writes through (store.open/save.*); the warm
    // restart loads from disk (store.load.*).
    let cold = run_once("cold");
    let warm = run_once("warm");
    assert_eq!(cold, reference, "zero-prob plan must not perturb results");
    assert_eq!(warm, reference, "warm restart bit-identical");

    assert_eq!(faultpoint::total_fired(), 0, "p=0 never fires");
    let seen = faultpoint::seen_sites();
    for site in faultpoint::CATALOG {
        assert!(
            seen.iter().any(|s| s == site),
            "site '{site}' never checked in; seen: {seen:?}"
        );
    }
    faultpoint::clear();
}

/// A waiter parked behind a coalesced leader keeps its OWN deadline:
/// when it lapses before the slow leader finishes, the waiter gets its
/// own typed `"rejected":"deadline"` instead of a result it no longer
/// wants, while the unbounded leader completes normally.
#[test]
fn parked_waiter_expires_on_its_own_deadline_behind_a_slow_leader() {
    let _g = faultpoint::test_guard();
    faultpoint::install_from_spec("engine.layer=delay:60ms@1", 4).unwrap();
    let server = CompressionServer::start(cfg());
    // Non-db-backed spec: takes the coalescing-table path, not the
    // batch scheduler.
    let spec = obc::coordinator::jobs::JobSpec::Prune {
        method: obc::coordinator::methods::PruneMethod::ExactObs,
        sparsity: 0.45,
        scope: obc::coordinator::engine::LayerScope::All,
    };
    let (tx, rx) = mpsc::channel();
    server.submit(SYNTHETIC_MODEL, spec.clone(), Some("lead".into()), tx.clone()).unwrap();
    // Let the leader claim the coalescing slot and start its first
    // (delayed) layer before the identical bounded waiter arrives.
    std::thread::sleep(Duration::from_millis(30));
    server
        .submit_with_deadline(
            SYNTHETIC_MODEL,
            spec,
            Some("late".into()),
            Some(Duration::from_millis(40)),
            tx,
        )
        .unwrap();
    let resps: Vec<Response> = rx.iter().collect();
    assert_eq!(resps.len(), 2, "both answered");
    let by_id = |id: &str| {
        resps
            .iter()
            .find(|r| r.client_id.as_deref() == Some(id))
            .unwrap_or_else(|| panic!("no response for {id}"))
    };
    assert!(by_id("lead").outcome.is_ok(), "{:?}", by_id("lead").outcome);
    let late = by_id("late");
    let err = late.outcome.as_ref().unwrap_err();
    assert!(err.starts_with("deadline exceeded"), "{err}");
    assert!(err.contains("parked behind a shared execution"), "own typed rejection: {err}");
    assert_eq!(late.to_json().get("rejected").and_then(|v| v.as_str()), Some("deadline"));
    let m = server.metrics_json();
    assert_eq!(counter(&m, "jobs_deadline_expired"), 1.0);
    assert_eq!(counter(&m, "jobs_coalesced"), 1.0, "the waiter did park");
    server.shutdown();
    faultpoint::clear();
}

/// The batched edition of the same contract: an admission-window group
/// member whose deadline lapses while the window is open (or the shared
/// build runs) gets its own typed rejection — the group leader's result
/// is not silently handed to a client that already timed out.
#[test]
fn batched_group_member_expires_on_its_own_deadline() {
    let _g = faultpoint::test_guard();
    let lines = vec![
        r#"{"id":"lead","model":"synthetic","op":"solve","target":"flop","value":1.5,"grid":[0,0.5,0.9]}"#
            .to_string(),
        r#"{"id":"late","model":"synthetic","op":"solve","target":"flop","value":1.5,"grid":[0,0.5,0.9],"deadline_ms":50}"#
            .to_string(),
    ];
    // One worker + a 200ms admission window: the worker pops "lead",
    // holds the window open, drains the identical "late" into the
    // group — and the window alone outlives late's 50ms budget.
    let config = ServerConfig {
        workers: 1,
        batch_window: Some(Duration::from_millis(200)),
        ..cfg()
    };
    let (jobs, ack) = stdin_run(config, &lines);
    assert_eq!(jobs.len(), 2, "both requests answered");
    let by_id = |id: &str| {
        jobs.iter()
            .map(|l| obc::util::json::parse(l).unwrap())
            .find(|j| j.get("id").and_then(|v| v.as_str()) == Some(id))
            .unwrap_or_else(|| panic!("no response for {id}: {jobs:?}"))
    };
    let lead = by_id("lead");
    assert_eq!(lead.get("ok").and_then(|v| v.as_bool()), Some(true), "{jobs:?}");
    let late = by_id("late");
    assert_eq!(late.get("ok").and_then(|v| v.as_bool()), Some(false), "{jobs:?}");
    assert_eq!(late.get("rejected").and_then(|v| v.as_str()), Some("deadline"), "{jobs:?}");
    let msg = late.get("error").and_then(|v| v.as_str()).unwrap();
    assert!(msg.starts_with("deadline exceeded"), "{msg}");
    assert!(msg.contains("parked behind a shared execution"), "{msg}");
    assert_eq!(counter(&ack, "batch_groups"), 1.0, "the two jobs did group");
    assert_eq!(counter(&ack, "jobs_deadline_expired"), 1.0);
    assert_eq!(counter(&ack, "jobs_completed"), 1.0);
    assert_eq!(counter(&ack, "jobs_failed"), 1.0);
    faultpoint::clear();
}
