//! The arena sweep engine's contract: **bit-identical** to the
//! fresh-clone reference implementations on every path (unstructured,
//! N:M, block, OBQ dense/sparse), robust to dirty arena reuse across
//! layers of different shapes, and **allocation-free** in steady state
//! (verified with a counting global allocator).

//! (The zero-allocation steady-state assertion lives in its own binary,
//! `rust/tests/arena_alloc_free.rs`, because its process-wide allocation
//! counters must not race other tests' threads.)

use obc::compress::exact_obs::{self, reference, ObsOpts};
use obc::compress::hessian::LayerHessian;
use obc::compress::obq::{self, ObqOpts};
use obc::compress::quant::Grid;
use obc::compress::sweep;
use obc::linalg::Mat;
use obc::util::pool::ThreadPool;
use obc::util::precision::Precision;
use obc::util::proptest as pt;
use obc::util::scratch::Scratch;

fn setup(d_row: usize, d_col: usize, seed: u64) -> (Mat, LayerHessian) {
    let w = Mat::randn(d_row, d_col, seed);
    let x = Mat::randn(d_col, d_col * 2 + 8, seed + 5000);
    (w, LayerHessian::from_inputs(&x, 1e-8))
}

/// Randomized configs: the arena pipeline must equal the reference
/// pipeline to the last ulp — weights, error, sparsity — including when
/// the same worker arenas are reused (dirty) across consecutive cases of
/// different dimensions.
#[test]
fn arena_bit_identical_to_reference_across_configs() {
    let pool = ThreadPool::new(3);
    pt::check(0xa7e4a, 18, |g| {
        let d_row = g.usize_in(1, 6);
        let d = g.usize_in(4, 6) * 4; // multiple of 4 for N:M and blocks
        let seed = g.rng.next_u64();
        let (w, h) = setup(d_row, d, seed);

        // Unstructured at a random sparsity and trace cap.
        let sparsity = g.f64_in(0.2, 0.9);
        let opts =
            ObsOpts { trace_cap: if g.bool() { 1.0 } else { 0.75 }, ..Default::default() };
        let a = exact_obs::prune_unstructured_on(&pool, &w, &h, sparsity, &opts);
        let r = reference::prune_unstructured_on(&pool, &w, &h, sparsity, &opts);
        if a.w.data != r.w.data {
            return Err(format!("unstructured weights diverged (d={d}, s={sparsity})"));
        }
        if a.sq_err != r.sq_err || a.sparsity != r.sparsity {
            return Err("unstructured err/sparsity diverged".into());
        }

        // N:M.
        let (n_keep, m) = if g.bool() { (2, 4) } else { (4, 8) };
        let an = exact_obs::prune_nm_on(&pool, &w, &h, n_keep, m);
        let rn = reference::prune_nm_on(&pool, &w, &h, n_keep, m);
        if an.w.data != rn.w.data {
            return Err(format!("{n_keep}:{m} weights diverged (d={d})"));
        }

        // Block sparsity.
        let c = [1usize, 2, 4][g.usize_in(0, 2)];
        let ab = exact_obs::prune_block_on(&pool, &w, &h, 0.5, c);
        let rb = reference::prune_block(&w, &h, 0.5, c);
        if ab.w.data != rb.w.data {
            return Err(format!("block c={c} weights diverged (d={d})"));
        }
        if ab.sq_err != rb.sq_err {
            return Err(format!("block c={c} err diverged"));
        }

        // OBQ dense.
        let bits = g.usize_in(2, 4) as u32;
        let grids =
            obc::compress::quant::fit_grids_per_row(&w, bits, false, Default::default());
        let oq = ObqOpts::new(bits);
        let aq = obq::quantize_with_grids_on(&pool, &w, &h, &grids, &oq);
        let rq = obq::quantize_with_grids_ref_on(&pool, &w, &h, &grids, &oq);
        if aq.w.data != rq.w.data {
            return Err(format!("OBQ weights diverged (d={d}, bits={bits})"));
        }

        // OBQ on the pruned matrix (sparse pre-elimination path).
        let asq = obq::quantize_sparse_on(&pool, &a.w, &h, &oq);
        let rsq = obq::quantize_sparse_ref(&a.w, &h, &oq);
        if asq.w.data != rsq.w.data {
            return Err(format!("sparse OBQ weights diverged (d={d})"));
        }
        Ok(())
    });
}

/// Rank-B lazy batching property: for every sweep kind, `batch = 1` is
/// **bit-identical** to the rank-1 engine (it *is* the rank-1 engine),
/// and `batch > 1` — including B = d, one flush for the whole sweep —
/// eliminates in the **same order** with weights within the
/// reassociation tolerance. N:M block validity must survive batching.
#[test]
fn rank_b_batches_match_rank1_across_configs() {
    let pool = ThreadPool::new(3);
    let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * (1.0 + b.abs());
    pt::check(0xb47c8, 14, |g| {
        let d_row = g.usize_in(1, 5);
        let d = g.usize_in(3, 7) * 4;
        let seed = g.rng.next_u64();
        let (w, h) = setup(d_row, d, seed);
        let sparsity = g.f64_in(0.3, 0.8);
        let batches = [1usize, 2, 8, d];
        let b = batches[g.usize_in(0, batches.len() - 1)];

        // Unstructured: opts.batch plumbed through sweep_all_rows.
        let o1 = ObsOpts { trace_cap: 1.0, ..Default::default() };
        let ob = ObsOpts { trace_cap: 1.0, batch: b, ..Default::default() };
        let r1 = exact_obs::prune_unstructured_on(&pool, &w, &h, sparsity, &o1);
        let rb = exact_obs::prune_unstructured_on(&pool, &w, &h, sparsity, &ob);
        if b == 1 && rb.w.data != r1.w.data {
            return Err(format!("B=1 not bit-identical (d={d})"));
        }
        for (i, (&a, &r)) in rb.w.data.iter().zip(&r1.w.data).enumerate() {
            // Same support (same elimination order) …
            if (a == 0.0) != (r == 0.0) {
                return Err(format!("B={b}: support diverged at {i} (d={d})"));
            }
            // … and surviving weights within tolerance.
            if !close(a as f64, r as f64) {
                return Err(format!("B={b}: weight {i} drifted {a} vs {r} (d={d})"));
            }
        }

        // N:M through the batched entry point: pattern stays valid and
        // matches the rank-1 support.
        let nm1 =
            exact_obs::prune_nm_batched_on(&pool, &w, &h, 2, 4, 1, Precision::F64);
        let nmb =
            exact_obs::prune_nm_batched_on(&pool, &w, &h, 2, 4, b, Precision::F64);
        for row in 0..d_row {
            for blk in 0..d / 4 {
                let nz = (0..4).filter(|i| nmb.w.at(row, blk * 4 + i) != 0.0).count();
                if nz != 2 {
                    return Err(format!("B={b}: row {row} block {blk} has {nz} nz"));
                }
            }
        }
        for (i, (&a, &r)) in nmb.w.data.iter().zip(&nm1.w.data).enumerate() {
            if (a == 0.0) != (r == 0.0) {
                return Err(format!("B={b}: N:M support diverged at {i}"));
            }
            if !close(a as f64, r as f64) {
                return Err(format!("B={b}: N:M weight {i} drifted"));
            }
        }

        // OBQ dense + sparse through opts.batch.
        let bits = g.usize_in(2, 4) as u32;
        let grids =
            obc::compress::quant::fit_grids_per_row(&w, bits, false, Default::default());
        let q1 = ObqOpts { batch: 1, ..ObqOpts::new(bits) };
        let qb = ObqOpts { batch: b, ..ObqOpts::new(bits) };
        let a1 = obq::quantize_with_grids_on(&pool, &w, &h, &grids, &q1);
        let ab = obq::quantize_with_grids_on(&pool, &w, &h, &grids, &qb);
        if b == 1 && ab.w.data != a1.w.data {
            return Err(format!("B=1 OBQ not bit-identical (d={d})"));
        }
        for (i, (&a, &r)) in ab.w.data.iter().zip(&a1.w.data).enumerate() {
            if !close(a as f64, r as f64) {
                return Err(format!("B={b}: OBQ weight {i} drifted {a} vs {r}"));
            }
        }
        let s1 = obq::quantize_sparse_on(&pool, &r1.w, &h, &q1);
        let sb = obq::quantize_sparse_on(&pool, &r1.w, &h, &qb);
        for (i, (&a, &r)) in sb.w.data.iter().zip(&s1.w.data).enumerate() {
            if (a == 0.0) != (r == 0.0) {
                return Err(format!("B={b}: sparse OBQ support diverged at {i}"));
            }
            if !close(a as f64, r as f64) {
                return Err(format!("B={b}: sparse OBQ weight {i} drifted"));
            }
        }
        Ok(())
    });
}

/// Deliberately dirty a private arena with a large layer, then sweep a
/// smaller layer: results must equal a fresh arena's bit-for-bit. This
/// pins the `begin()` reset contract (nothing read before initialized).
#[test]
fn dirty_arena_across_layers_matches_fresh() {
    let (w_big, h_big) = setup(1, 24, 900);
    let (w_small, h_small) = setup(1, 9, 901);

    let mut dirty = Scratch::new();
    // Dirty it: full sweep of the big layer, then a block sweep.
    sweep::prune_sweep(&mut dirty, w_big.row(0), &h_big.hinv, 24, |_, _| true).unwrap();
    sweep::block_sweep(&mut dirty, w_big.row(0), &h_big.hinv, 4, 3);

    // Now the small layer on the dirty arena vs a fresh one.
    let mut fresh = Scratch::new();
    sweep::prune_sweep(&mut dirty, w_small.row(0), &h_small.hinv, 5, |_, _| true).unwrap();
    let dirty_out = dirty.out()[..9].to_vec();
    let dirty_order = dirty.trace_order.clone();
    sweep::prune_sweep(&mut fresh, w_small.row(0), &h_small.hinv, 5, |_, _| true).unwrap();
    assert_eq!(dirty_out, fresh.out()[..9].to_vec());
    assert_eq!(dirty_order, fresh.trace_order);

    // Same for the OBQ sweep.
    let grid = Grid { scale: 0.25, zero: 8.0, maxq: 15.0 };
    sweep::quant_sweep(&mut dirty, w_small.row(0), &h_small.hinv, &grid, true).unwrap();
    let dirty_q = dirty.out()[..9].to_vec();
    sweep::quant_sweep(&mut fresh, w_small.row(0), &h_small.hinv, &grid, true).unwrap();
    assert_eq!(dirty_q, fresh.out()[..9].to_vec());
}

/// Serial vs pooled arena runs stay bit-identical (the PR-1 determinism
/// contract carried over to the arena engine), and the N:M pattern stays
/// valid through the arena path.
#[test]
fn pooled_arena_still_deterministic_and_valid() {
    let (w, h) = setup(9, 20, 960);
    let serial = ThreadPool::new(1);
    let pooled = ThreadPool::new(4);
    let a = exact_obs::prune_unstructured_on(&serial, &w, &h, 0.6, &ObsOpts::default());
    let b = exact_obs::prune_unstructured_on(&pooled, &w, &h, 0.6, &ObsOpts::default());
    assert_eq!(a.w.data, b.w.data);

    let nm = exact_obs::prune_nm_on(&pooled, &w, &h, 2, 4);
    for row in 0..9 {
        for blk in 0..5 {
            let nz = (0..4).filter(|i| nm.w.at(row, blk * 4 + i) != 0.0).count();
            assert_eq!(nz, 2, "row {row} block {blk}");
        }
    }
}

/// Tracing never touches a float: the same sweep run with a span
/// collector armed (including across the pool fan-out) is bit-identical
/// to the untraced run, and the collector actually recorded phases.
#[test]
fn armed_tracing_is_bitwise_invisible_to_kernels() {
    use obc::util::trace;
    use std::sync::Arc;

    let (w, h) = setup(9, 20, 970);
    let pooled = ThreadPool::new(4);
    let untraced = exact_obs::prune_unstructured_on(&pooled, &w, &h, 0.6, &ObsOpts::default());

    let profile = Arc::new(trace::Profile::new());
    let traced = {
        let _g = trace::set(Some(Arc::clone(&profile)));
        exact_obs::prune_unstructured_on(&pooled, &w, &h, 0.6, &ObsOpts::default())
    };
    for (a, b) in untraced.w.data.iter().zip(traced.w.data.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "tracing must be bitwise invisible");
    }
    assert_eq!(untraced.sq_err.to_bits(), traced.sq_err.to_bits());
    assert!(profile.total_ns() > 0, "the collector must have recorded spans");
    let names: Vec<&str> = profile.phases().iter().map(|(n, _, _)| *n).collect();
    assert!(
        names.contains(&"sweep.flush") || names.contains(&"pool.job"),
        "expected kernel phases in {names:?}"
    );
}
