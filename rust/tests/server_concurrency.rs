//! The serving-layer acceptance test: N simultaneous jobs against one
//! model must
//!
//! 1. calibrate exactly ONCE (single-flight registry),
//! 2. share the engine's database cache (one build, observed hits), and
//! 3. return results **bit-identical** to the same jobs run sequentially
//!    through the old `Pipeline` path.
//!
//! The TCP transport rides the same suite (`tcp` module below): N
//! concurrent localhost clients must see responses identical to the
//! stdin line protocol (modulo per-run timing/scheduling fields), and a
//! mid-batch `shutdown` must drain — one response per accepted job —
//! before the ack.
//!
//! Everything runs on the synthetic tiny pipeline — no `make artifacts`
//! dependency, debug-mode friendly.

use obc::coordinator::engine::{CompressionEngine, LayerScope};
use obc::coordinator::jobs::{DbKind, DbSpec, JobResult, JobSpec, TargetKind};
use obc::coordinator::methods::{PruneMethod, QuantMethod};
use obc::coordinator::pipeline::Pipeline;
use obc::server::registry::{SYNTHETIC_MODEL, SYNTHETIC_SEED};
use obc::server::{CompressionServer, Response, ServerConfig};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{mpsc, Arc};

fn sparsity_db_spec() -> DbSpec {
    DbSpec {
        kind: DbKind::Sparsity,
        method: PruneMethod::ExactObs,
        grid: vec![0.0, 0.5, 0.9],
        scope: LayerScope::All,
    }
}

/// The job batch: duplicates (j1a/j1b) test coalescing-or-recompute
/// identity, j3/j4 share one database build through the engine cache.
fn job_batch() -> Vec<(&'static str, JobSpec)> {
    vec![
        (
            "j1a",
            JobSpec::Prune {
                method: PruneMethod::ExactObs,
                sparsity: 0.5,
                scope: LayerScope::All,
            },
        ),
        (
            "j1b",
            JobSpec::Prune {
                method: PruneMethod::ExactObs,
                sparsity: 0.5,
                scope: LayerScope::All,
            },
        ),
        (
            "j2",
            JobSpec::Quant {
                method: QuantMethod::Obq,
                bits: 4,
                symmetric: false,
                scope: LayerScope::All,
                corrected: true,
            },
        ),
        (
            "j3",
            JobSpec::Solve { db: sparsity_db_spec(), target: TargetKind::Flop, value: 1.5 },
        ),
        (
            "j4",
            JobSpec::Solve { db: sparsity_db_spec(), target: TargetKind::Flop, value: 2.0 },
        ),
    ]
}

#[test]
fn concurrent_jobs_calibrate_once_share_db_cache_and_match_sequential() {
    // --- concurrent: all jobs queued up-front, 4 workers race ---------
    let server = CompressionServer::start(ServerConfig {
        workers: 4,
        queue_cap: 16,
        models_dir: PathBuf::from("/nonexistent"),
        synthetic_only: true,
        ..ServerConfig::default()
    });
    let (tx, rx) = mpsc::channel();
    for (id, spec) in job_batch() {
        server
            .submit(SYNTHETIC_MODEL, spec, Some(id.to_string()), tx.clone())
            .unwrap();
    }
    drop(tx);
    let responses: BTreeMap<String, Response> = rx
        .iter()
        .map(|r| (r.client_id.clone().unwrap(), r))
        .collect();
    assert_eq!(responses.len(), 5, "every job answered");

    // (1) Exactly one calibration despite 5 simultaneous jobs.
    let metrics = server.metrics_json();
    assert_eq!(
        metrics.get("calibrations").unwrap().as_f64().unwrap(),
        1.0,
        "single-flight calibration: {metrics}"
    );

    // (2) One database build shared by j3 and j4 (the build is a miss;
    // the other solve either hits the cache or coalesces — both count
    // as exactly one build).
    let misses = metrics.get("db_cache_misses").unwrap().as_f64().unwrap();
    assert_eq!(misses, 1.0, "one db build: {metrics}");
    let hits = metrics.get("db_cache_hits").unwrap().as_f64().unwrap();
    assert!(hits >= 1.0, "second solve must reuse the db: {metrics}");

    // Duplicate jobs agree bit-for-bit however they were scheduled.
    let bits = |id: &str| -> u64 {
        responses[id]
            .outcome
            .as_ref()
            .unwrap_or_else(|e| panic!("{id} failed: {e}"))
            .metric()
            .unwrap()
            .to_bits()
    };
    assert_eq!(bits("j1a"), bits("j1b"), "duplicate jobs identical");

    // --- sequential: the old Pipeline path on an identically-seeded
    // engine (fresh calibration, fresh caches, no server) --------------
    let p = Pipeline::from_engine(Arc::new(CompressionEngine::synthetic(SYNTHETIC_SEED).unwrap()));
    let seq_prune = p.run_uniform_sparsity(PruneMethod::ExactObs, 0.5, LayerScope::All);
    let seq_quant = p.run_quant(QuantMethod::Obq, 4, false, LayerScope::All, true);
    let db = p.build_sparsity_db(PruneMethod::ExactObs, &[0.0, 0.5, 0.9], LayerScope::All);
    let seq_solve_15 = p.eval_flop_target(&db, LayerScope::All, 1.5).unwrap();
    let seq_solve_20 = p.eval_flop_target(&db, LayerScope::All, 2.0).unwrap();

    // (3) Bit-identical results, concurrent vs sequential.
    assert_eq!(bits("j1a"), seq_prune.to_bits(), "prune differs from Pipeline path");
    assert_eq!(bits("j2"), seq_quant.to_bits(), "quant differs from Pipeline path");
    for (id, (seq_metric, seq_achieved)) in [("j3", seq_solve_15), ("j4", seq_solve_20)] {
        match responses[id].outcome.as_ref().unwrap() {
            JobResult::Solved { metric, achieved, .. } => {
                assert_eq!(metric.to_bits(), seq_metric.to_bits(), "{id} metric differs");
                assert_eq!(achieved.to_bits(), seq_achieved.to_bits(), "{id} achieved differs");
            }
            other => panic!("{id}: expected Solved, got {other:?}"),
        }
    }

    // Graceful shutdown still works after the batch.
    server.shutdown();
    let health = server.health_json();
    assert_eq!(health.get("queue_depth").unwrap().as_f64().unwrap(), 0.0);
}

/// Queue-depth metrics see the burst; per-job timing fields are recorded.
#[test]
fn metrics_record_queue_depth_and_timings() {
    let server = CompressionServer::start(ServerConfig {
        workers: 1, // one worker → jobs pile up in the queue
        queue_cap: 8,
        models_dir: PathBuf::from("/nonexistent"),
        synthetic_only: true,
        ..ServerConfig::default()
    });
    let (tx, rx) = mpsc::channel();
    for i in 0..3 {
        server
            .submit(SYNTHETIC_MODEL, JobSpec::Dense, Some(format!("d{i}")), tx.clone())
            .unwrap();
    }
    drop(tx);
    let responses: Vec<Response> = rx.iter().collect();
    assert_eq!(responses.len(), 3);
    // Coalesced or not, all three carry timing fields and one executed.
    assert!(responses.iter().all(|r| r.queue_s >= 0.0 && r.exec_s >= 0.0));
    assert!(responses.iter().any(|r| !r.coalesced && r.exec_s > 0.0));
    let m = server.metrics_json();
    // Peak depth is scheduling-dependent (the single worker may pop a
    // job between two pushes), but the high-water mark must have seen
    // at least one queued job.
    assert!(m.get("queue_depth_peak").unwrap().as_f64().unwrap() >= 1.0, "{m}");
    assert_eq!(
        m.get("jobs_submitted").unwrap().as_f64().unwrap(),
        3.0
    );
    assert_eq!(
        m.get("jobs_completed").unwrap().as_f64().unwrap(),
        3.0
    );
    assert!(m.get("exec_seconds_total").unwrap().as_f64().unwrap() > 0.0);
    server.shutdown();
}

mod tcp {
    use super::*;
    use obc::server::net::serve_tcp;
    use obc::server::run_line_protocol;
    use obc::util::json::Json;
    use std::io::{BufRead, BufReader, Write as IoWrite};
    use std::net::{TcpListener, TcpStream};
    use std::sync::Mutex;
    use std::time::Duration;

    fn cfg() -> ServerConfig {
        ServerConfig {
            workers: 4,
            queue_cap: 32,
            models_dir: PathBuf::from("/nonexistent"),
            synthetic_only: true,
            ..ServerConfig::default()
        }
    }

    /// The job batch every client sends (same shape as the smoke batch:
    /// dense, prune, quant, and a solver target over a shared db).
    fn job_lines() -> Vec<String> {
        vec![
            r#"{"id":"d1","model":"synthetic","op":"dense"}"#.into(),
            r#"{"id":"p1","model":"synthetic","op":"prune","method":"exactobs","sparsity":0.5}"#
                .into(),
            r#"{"id":"q1","model":"synthetic","op":"quant","method":"obq","bits":4}"#.into(),
            r#"{"id":"s1","model":"synthetic","op":"solve","target":"flop","value":1.5,"grid":[0,0.5,0.9]}"#
                .into(),
        ]
    }

    /// Strip the fields that legitimately differ between runs and
    /// schedules — sequence numbers, timings, and the cache/coalescing
    /// provenance flags (a coalesced response is the SAME result by
    /// construction; which request built the shared db is a race). The
    /// payload that remains (op, id, metrics, achieved, entries, …)
    /// must be byte-identical, f64 bits included: `Json` objects
    /// serialize with sorted keys and shortest-roundtrip floats.
    fn normalize(line: &str) -> String {
        match obc::util::json::parse(line).unwrap_or_else(|e| panic!("bad line {line}: {e}")) {
            Json::Obj(mut m) => {
                let volatile =
                    ["seq", "queue_seconds", "seconds", "coalesced", "cached", "cached_db"];
                for key in volatile {
                    m.remove(key);
                }
                Json::Obj(m).to_string_compact()
            }
            other => other.to_string_compact(),
        }
    }

    /// Run the reference batch through the in-process stdin protocol and
    /// return its normalized, sorted job responses.
    fn stdin_reference() -> Vec<String> {
        #[derive(Clone, Default)]
        struct SharedBuf(Arc<Mutex<Vec<u8>>>);
        impl std::io::Write for SharedBuf {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(b);
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let mut input = job_lines().join("\n");
        input.push_str("\n{\"op\":\"shutdown\"}\n");
        let buf = SharedBuf::default();
        run_line_protocol(cfg(), input.as_bytes(), buf.clone()).unwrap();
        let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        let mut out: Vec<String> = text
            .lines()
            .filter(|l| l.contains("\"id\":")) // job responses only
            .map(normalize)
            .collect();
        out.sort();
        assert_eq!(out.len(), job_lines().len(), "reference run answered everything: {text}");
        out
    }

    /// ≥ 8 concurrent TCP clients, each sending the full batch, must
    /// all receive exactly the stdin protocol's responses.
    #[test]
    fn eight_concurrent_tcp_clients_match_stdin_protocol() {
        let reference = stdin_reference();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || serve_tcp(cfg(), listener).unwrap());

        let clients: Vec<_> = (0..8)
            .map(|c| {
                let lines = job_lines();
                std::thread::spawn(move || {
                    let mut s = TcpStream::connect(addr).unwrap();
                    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
                    for l in &lines {
                        writeln!(s, "{l}").unwrap();
                    }
                    s.flush().unwrap();
                    let mut r = BufReader::new(s);
                    let mut got = Vec::new();
                    for _ in 0..lines.len() {
                        let mut line = String::new();
                        r.read_line(&mut line)
                            .unwrap_or_else(|e| panic!("client {c} read: {e}"));
                        assert!(!line.is_empty(), "client {c}: connection closed early");
                        got.push(normalize(line.trim()));
                    }
                    got.sort();
                    got
                })
            })
            .collect();
        for (c, h) in clients.into_iter().enumerate() {
            let got = h.join().unwrap();
            assert_eq!(got, reference, "client {c} diverged from the stdin protocol");
        }

        // Metrics over TCP carry the transport counters.
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        writeln!(s, "{{\"op\":\"metrics\"}}").unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut m = String::new();
        r.read_line(&mut m).unwrap();
        let mj = obc::util::json::parse(m.trim()).unwrap();
        assert!(mj.get("net_connections_opened").unwrap().as_f64().unwrap() >= 8.0, "{m}");
        assert!(mj.get("net_bytes_in").unwrap().as_f64().unwrap() > 0.0, "{m}");
        assert!(mj.get("net_bytes_out").unwrap().as_f64().unwrap() > 0.0, "{m}");
        assert_eq!(
            mj.get("calibrations").unwrap().as_f64().unwrap(),
            1.0,
            "8 TCP clients share one single-flight calibration: {m}"
        );

        // Shutdown from this connection: drained ack is the final word.
        writeln!(s, "{{\"op\":\"shutdown\"}}").unwrap();
        let mut ack = String::new();
        r.read_line(&mut ack).unwrap();
        let aj = obc::util::json::parse(ack.trim()).unwrap();
        assert_eq!(aj.get("op").unwrap().as_str().unwrap(), "shutdown", "{ack}");
        assert!(aj.get("net_connections_opened").is_some(), "{ack}");
        server.join().unwrap();
    }

    /// Mid-batch shutdown: jobs accepted before the drain still get
    /// their responses on their own connection — exactly one line per
    /// request, each either a result or a typed rejection.
    #[test]
    fn mid_batch_shutdown_drains_every_accepted_job() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || serve_tcp(cfg(), listener).unwrap());

        let mut a = TcpStream::connect(addr).unwrap();
        a.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        let n = 6;
        for i in 0..n {
            // Distinct sparsities: six genuinely distinct jobs in flight.
            writeln!(
                a,
                "{{\"id\":\"a{i}\",\"model\":\"synthetic\",\"op\":\"prune\",\"method\":\"gmp\",\"sparsity\":0.{}}}",
                3 + i
            )
            .unwrap();
        }
        a.flush().unwrap();
        // Let the reader thread ingest the batch, then pull the plug
        // from a second connection.
        std::thread::sleep(Duration::from_millis(200));
        let mut b = TcpStream::connect(addr).unwrap();
        b.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        writeln!(b, "{{\"op\":\"shutdown\"}}").unwrap();

        // A: one response per request, drained before its connection
        // closes; accepted jobs succeed, post-close submissions are
        // typed rejections (never silence).
        let mut ra = BufReader::new(a.try_clone().unwrap());
        let mut ok = 0;
        let mut rejected = 0;
        for i in 0..n {
            let mut line = String::new();
            ra.read_line(&mut line).unwrap();
            assert!(!line.is_empty(), "response {i} missing: connection closed before drain");
            let j = obc::util::json::parse(line.trim()).unwrap();
            if j.get("ok").unwrap().as_bool().unwrap() {
                ok += 1;
            } else {
                let err = j.get("error").unwrap().as_str().unwrap().to_string();
                assert!(err.contains("shutting down"), "unexpected error: {err}");
                rejected += 1;
            }
        }
        assert_eq!(ok + rejected, n, "every request answered exactly once");
        assert!(ok >= 1, "at least the in-flight work completed during the drain");

        // B: the post-drain ack arrives after A's drain finished.
        let mut rb = BufReader::new(b);
        let mut ack = String::new();
        rb.read_line(&mut ack).unwrap();
        let aj = obc::util::json::parse(ack.trim()).unwrap();
        assert_eq!(aj.get("op").unwrap().as_str().unwrap(), "shutdown", "{ack}");
        let answered = aj.get("jobs_completed").unwrap().as_f64().unwrap() as usize;
        let refused = aj.get("jobs_rejected").unwrap().as_f64().unwrap() as usize;
        assert_eq!(answered + refused, n, "ack counters account for the whole batch: {ack}");

        // A's connection reaches EOF once the server wound down.
        let mut tail = String::new();
        while ra.read_line(&mut tail).unwrap_or(0) > 0 {}
        server.join().unwrap();
    }

    /// Overlapping database-backed jobs every batching client sends:
    /// one (model, method, grid) pool family across both layer scopes —
    /// a build plus two solver targets.
    fn overlapping_lines() -> Vec<String> {
        vec![
            r#"{"id":"g1","model":"synthetic","op":"db","grid":[0,0.5,0.9]}"#.into(),
            r#"{"id":"g2","model":"synthetic","op":"solve","target":"flop","value":1.5,"grid":[0,0.5,0.9]}"#
                .into(),
            r#"{"id":"g3","model":"synthetic","op":"solve","target":"flop","value":2.0,"grid":[0,0.5,0.9],"scope":"inner"}"#
                .into(),
        ]
    }

    /// Run ONE job alone on a fresh single-worker server (nothing to
    /// group with, nothing cached) and return its normalized response —
    /// the strictly-sequential reference for the batch scheduler.
    fn run_alone(line: &str) -> String {
        #[derive(Clone, Default)]
        struct SharedBuf(Arc<Mutex<Vec<u8>>>);
        impl std::io::Write for SharedBuf {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(b);
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let input = format!("{line}\n{{\"op\":\"shutdown\"}}\n");
        let buf = SharedBuf::default();
        run_line_protocol(ServerConfig { workers: 1, ..cfg() }, input.as_bytes(), buf.clone())
            .unwrap();
        let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        let resp = text
            .lines()
            .find(|l| l.contains("\"id\":"))
            .unwrap_or_else(|| panic!("no response for {line}: {text}"));
        normalize(resp)
    }

    /// Tentpole acceptance: concurrent TCP clients with overlapping
    /// layer sets, grouped by the admission window into pooled
    /// executions, must receive responses **f64-bit-identical** to each
    /// job run one-at-a-time on a fresh server — and the metrics must
    /// prove at least one group actually shared an execution.
    #[test]
    fn batched_tcp_clients_bit_identical_to_one_at_a_time() {
        let mut reference: Vec<String> =
            overlapping_lines().iter().map(|l| run_alone(l)).collect();
        reference.sort();

        let config = ServerConfig { batch_window: Some(Duration::from_millis(250)), ..cfg() };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || serve_tcp(config, listener).unwrap());

        let clients: Vec<_> = (0..6)
            .map(|c| {
                let lines = overlapping_lines();
                std::thread::spawn(move || {
                    let mut s = TcpStream::connect(addr).unwrap();
                    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
                    for l in &lines {
                        writeln!(s, "{l}").unwrap();
                    }
                    s.flush().unwrap();
                    let mut r = BufReader::new(s);
                    let mut got = Vec::new();
                    for _ in 0..lines.len() {
                        let mut line = String::new();
                        r.read_line(&mut line)
                            .unwrap_or_else(|e| panic!("client {c} read: {e}"));
                        assert!(!line.is_empty(), "client {c}: connection closed early");
                        got.push(normalize(line.trim()));
                    }
                    got.sort();
                    got
                })
            })
            .collect();
        for (c, h) in clients.into_iter().enumerate() {
            let got = h.join().unwrap();
            assert_eq!(got, reference, "client {c}: batched run diverged from sequential");
        }

        // The grouping must be real: at least one admission window held
        // two or more jobs that shared one pooled execution.
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        writeln!(s, "{{\"op\":\"shutdown\"}}").unwrap();
        let mut r = BufReader::new(s);
        let mut ack = String::new();
        r.read_line(&mut ack).unwrap();
        let aj = obc::util::json::parse(ack.trim()).unwrap();
        let groups = aj.get("batch_groups").unwrap().as_f64().unwrap();
        assert!(groups >= 1.0, "no cross-request group ever formed: {ack}");
        let peak = aj.get("batch_occupancy_peak").unwrap().as_f64().unwrap();
        assert!(peak >= 2.0, "no window ever held two jobs: {ack}");
        server.join().unwrap();
    }

    /// Streaming acceptance: a `stream:true` db build over the full
    /// Eq. 10 default grid delivers at least one `{"chunk":...}` line
    /// per sparsity level before the final response, with each layer's
    /// levels arriving in order.
    #[test]
    fn streaming_db_build_chunks_every_level_before_the_final() {
        #[derive(Clone, Default)]
        struct SharedBuf(Arc<Mutex<Vec<u8>>>);
        impl std::io::Write for SharedBuf {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(b);
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        // No explicit grid: the build runs the paper-default Eq. 10
        // grid. An outbox far above layers x levels keeps this
        // deterministic — nothing can drop.
        let input = concat!(
            "{\"id\":\"bd\",\"model\":\"synthetic\",\"op\":\"db\",\"stream\":true}\n",
            "{\"op\":\"shutdown\"}\n",
        );
        let buf = SharedBuf::default();
        run_line_protocol(
            ServerConfig { workers: 1, chunk_outbox: 1 << 14, ..cfg() },
            input.as_bytes(),
            buf.clone(),
        )
        .unwrap();
        let text = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        let final_idx = lines
            .iter()
            .position(|l| l.contains("\"id\":\"bd\"") && l.contains("\"ok\":true"))
            .unwrap_or_else(|| panic!("no final response: {text}"));
        assert!(
            lines[final_idx..].iter().all(|l| !l.contains("\"chunk\"")),
            "chunks must precede the final response: {text}"
        );

        let chunks: Vec<Json> = lines[..final_idx]
            .iter()
            .filter(|l| l.contains("\"chunk\""))
            .map(|l| obc::util::json::parse(l).unwrap())
            .collect();
        assert!(!chunks.is_empty(), "streaming build emitted no chunks: {text}");
        let levels = chunks[0].get("levels").unwrap().as_f64().unwrap() as usize;
        let expected = obc::solver::sparsity_grid(0.1, 0.95).len();
        assert_eq!(levels, expected, "full Eq. 10 grid");
        // Every level is covered by at least one chunk, every chunk
        // carries the job identity, and per-layer levels ascend.
        let mut seen = vec![false; levels];
        let mut last_level: BTreeMap<String, usize> = BTreeMap::new();
        for c in &chunks {
            assert_eq!(c.get("chunk").unwrap().as_str().unwrap(), "db_level");
            assert_eq!(c.get("id").unwrap().as_str().unwrap(), "bd");
            let layer = c.get("layer").unwrap().as_str().unwrap().to_string();
            let li = c.get("level").unwrap().as_f64().unwrap() as usize;
            assert!(li < levels, "level {li} out of range");
            if let Some(prev) = last_level.get(&layer) {
                assert!(li > *prev, "layer {layer}: levels must ascend ({prev} -> {li})");
            }
            last_level.insert(layer, li);
            seen[li] = true;
        }
        assert!(
            seen.iter().all(|s| *s),
            "every sparsity level must stream at least one chunk: {seen:?}"
        );

        // The ack's counters saw the stream (nothing dropped under the
        // oversized outbox).
        let ack = obc::util::json::parse(lines.last().unwrap()).unwrap();
        let sent = ack.get("stream_chunks_sent").unwrap().as_f64().unwrap();
        assert!(sent >= levels as f64, "{ack}");
        assert_eq!(ack.get("stream_chunks_dropped").unwrap().as_f64().unwrap(), 0.0, "{ack}");
    }
}
