//! The serving-layer acceptance test: N simultaneous jobs against one
//! model must
//!
//! 1. calibrate exactly ONCE (single-flight registry),
//! 2. share the engine's database cache (one build, observed hits), and
//! 3. return results **bit-identical** to the same jobs run sequentially
//!    through the old `Pipeline` path.
//!
//! Everything runs on the synthetic tiny pipeline — no `make artifacts`
//! dependency, debug-mode friendly.

use obc::coordinator::engine::{CompressionEngine, LayerScope};
use obc::coordinator::jobs::{DbKind, DbSpec, JobResult, JobSpec, TargetKind};
use obc::coordinator::methods::{PruneMethod, QuantMethod};
use obc::coordinator::pipeline::Pipeline;
use obc::server::registry::{SYNTHETIC_MODEL, SYNTHETIC_SEED};
use obc::server::{CompressionServer, Response, ServerConfig};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{mpsc, Arc};

fn sparsity_db_spec() -> DbSpec {
    DbSpec {
        kind: DbKind::Sparsity,
        method: PruneMethod::ExactObs,
        grid: vec![0.0, 0.5, 0.9],
        scope: LayerScope::All,
    }
}

/// The job batch: duplicates (j1a/j1b) test coalescing-or-recompute
/// identity, j3/j4 share one database build through the engine cache.
fn job_batch() -> Vec<(&'static str, JobSpec)> {
    vec![
        (
            "j1a",
            JobSpec::Prune {
                method: PruneMethod::ExactObs,
                sparsity: 0.5,
                scope: LayerScope::All,
            },
        ),
        (
            "j1b",
            JobSpec::Prune {
                method: PruneMethod::ExactObs,
                sparsity: 0.5,
                scope: LayerScope::All,
            },
        ),
        (
            "j2",
            JobSpec::Quant {
                method: QuantMethod::Obq,
                bits: 4,
                symmetric: false,
                scope: LayerScope::All,
                corrected: true,
            },
        ),
        (
            "j3",
            JobSpec::Solve { db: sparsity_db_spec(), target: TargetKind::Flop, value: 1.5 },
        ),
        (
            "j4",
            JobSpec::Solve { db: sparsity_db_spec(), target: TargetKind::Flop, value: 2.0 },
        ),
    ]
}

#[test]
fn concurrent_jobs_calibrate_once_share_db_cache_and_match_sequential() {
    // --- concurrent: all jobs queued up-front, 4 workers race ---------
    let server = CompressionServer::start(ServerConfig {
        workers: 4,
        queue_cap: 16,
        models_dir: PathBuf::from("/nonexistent"),
        synthetic_only: true,
    });
    let (tx, rx) = mpsc::channel();
    for (id, spec) in job_batch() {
        server
            .submit(SYNTHETIC_MODEL, spec, Some(id.to_string()), tx.clone())
            .unwrap();
    }
    drop(tx);
    let responses: BTreeMap<String, Response> = rx
        .iter()
        .map(|r| (r.client_id.clone().unwrap(), r))
        .collect();
    assert_eq!(responses.len(), 5, "every job answered");

    // (1) Exactly one calibration despite 5 simultaneous jobs.
    let metrics = server.metrics_json();
    assert_eq!(
        metrics.get("calibrations").unwrap().as_f64().unwrap(),
        1.0,
        "single-flight calibration: {metrics}"
    );

    // (2) One database build shared by j3 and j4 (the build is a miss;
    // the other solve either hits the cache or coalesces — both count
    // as exactly one build).
    let misses = metrics.get("db_cache_misses").unwrap().as_f64().unwrap();
    assert_eq!(misses, 1.0, "one db build: {metrics}");
    let hits = metrics.get("db_cache_hits").unwrap().as_f64().unwrap();
    assert!(hits >= 1.0, "second solve must reuse the db: {metrics}");

    // Duplicate jobs agree bit-for-bit however they were scheduled.
    let bits = |id: &str| -> u64 {
        responses[id]
            .outcome
            .as_ref()
            .unwrap_or_else(|e| panic!("{id} failed: {e}"))
            .metric()
            .unwrap()
            .to_bits()
    };
    assert_eq!(bits("j1a"), bits("j1b"), "duplicate jobs identical");

    // --- sequential: the old Pipeline path on an identically-seeded
    // engine (fresh calibration, fresh caches, no server) --------------
    let p = Pipeline::from_engine(Arc::new(CompressionEngine::synthetic(SYNTHETIC_SEED).unwrap()));
    let seq_prune = p.run_uniform_sparsity(PruneMethod::ExactObs, 0.5, LayerScope::All);
    let seq_quant = p.run_quant(QuantMethod::Obq, 4, false, LayerScope::All, true);
    let db = p.build_sparsity_db(PruneMethod::ExactObs, &[0.0, 0.5, 0.9], LayerScope::All);
    let seq_solve_15 = p.eval_flop_target(&db, LayerScope::All, 1.5).unwrap();
    let seq_solve_20 = p.eval_flop_target(&db, LayerScope::All, 2.0).unwrap();

    // (3) Bit-identical results, concurrent vs sequential.
    assert_eq!(bits("j1a"), seq_prune.to_bits(), "prune differs from Pipeline path");
    assert_eq!(bits("j2"), seq_quant.to_bits(), "quant differs from Pipeline path");
    for (id, (seq_metric, seq_achieved)) in [("j3", seq_solve_15), ("j4", seq_solve_20)] {
        match responses[id].outcome.as_ref().unwrap() {
            JobResult::Solved { metric, achieved, .. } => {
                assert_eq!(metric.to_bits(), seq_metric.to_bits(), "{id} metric differs");
                assert_eq!(achieved.to_bits(), seq_achieved.to_bits(), "{id} achieved differs");
            }
            other => panic!("{id}: expected Solved, got {other:?}"),
        }
    }

    // Graceful shutdown still works after the batch.
    server.shutdown();
    let health = server.health_json();
    assert_eq!(health.get("queue_depth").unwrap().as_f64().unwrap(), 0.0);
}

/// Queue-depth metrics see the burst; per-job timing fields are recorded.
#[test]
fn metrics_record_queue_depth_and_timings() {
    let server = CompressionServer::start(ServerConfig {
        workers: 1, // one worker → jobs pile up in the queue
        queue_cap: 8,
        models_dir: PathBuf::from("/nonexistent"),
        synthetic_only: true,
    });
    let (tx, rx) = mpsc::channel();
    for i in 0..3 {
        server
            .submit(SYNTHETIC_MODEL, JobSpec::Dense, Some(format!("d{i}")), tx.clone())
            .unwrap();
    }
    drop(tx);
    let responses: Vec<Response> = rx.iter().collect();
    assert_eq!(responses.len(), 3);
    // Coalesced or not, all three carry timing fields and one executed.
    assert!(responses.iter().all(|r| r.queue_s >= 0.0 && r.exec_s >= 0.0));
    assert!(responses.iter().any(|r| !r.coalesced && r.exec_s > 0.0));
    let m = server.metrics_json();
    // Peak depth is scheduling-dependent (the single worker may pop a
    // job between two pushes), but the high-water mark must have seen
    // at least one queued job.
    assert!(m.get("queue_depth_peak").unwrap().as_f64().unwrap() >= 1.0, "{m}");
    assert_eq!(
        m.get("jobs_submitted").unwrap().as_f64().unwrap(),
        3.0
    );
    assert_eq!(
        m.get("jobs_completed").unwrap().as_f64().unwrap(),
        3.0
    );
    assert!(m.get("exec_seconds_total").unwrap().as_f64().unwrap() > 0.0);
    server.shutdown();
}
