//! Snapshot-store acceptance suite.
//!
//! Pins the durability contract of `obc::store`:
//!
//! 1. a database build **writes through** to disk and a fresh engine
//!    (same seed → same calibration fingerprint) **warm-starts** from
//!    the snapshot without rebuilding, bit-identically to a live build;
//! 2. every way a snapshot can be wrong — truncated file, flipped
//!    payload byte, wrong format version, foreign key, stale
//!    calibration fingerprint — is **rejected** (counted, quarantined)
//!    and degrades to a live build that is bit-identical to the
//!    no-store path, including the solver result computed over it;
//! 3. `db export` / `db import` hand a snapshot between stores with
//!    full revalidation;
//! 4. a **restarted server** answers a db-backed job from the store:
//!    the store-hit counter increments and the build counter does not.
//!
//! Everything runs on the synthetic tiny pipeline — no artifacts.

use obc::coordinator::engine::{CompressionEngine, LayerScope};
use obc::coordinator::jobs::{self, DbKind, DbSpec, JobResult, JobSpec, TargetKind};
use obc::coordinator::methods::PruneMethod;
use obc::db::ModelDb;
use obc::server::registry::{SYNTHETIC_MODEL, SYNTHETIC_SEED};
use obc::server::{CompressionServer, ServerConfig};
use obc::store::{format as snapfmt, SnapshotStore};
use std::path::{Path, PathBuf};
use std::sync::{mpsc, Arc};

fn tmp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("obc_store_rt_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn spec() -> DbSpec {
    DbSpec {
        kind: DbKind::Sparsity,
        method: PruneMethod::ExactObs,
        grid: vec![0.0, 0.5, 0.9],
        scope: LayerScope::All,
    }
}

fn engine_with_store(dir: &Path) -> (CompressionEngine, Arc<SnapshotStore>) {
    let engine = CompressionEngine::synthetic(SYNTHETIC_SEED).unwrap();
    let store = Arc::new(SnapshotStore::open(dir).unwrap());
    engine.attach_store(Arc::clone(&store));
    (engine, store)
}

/// Full bit-level identity of a database: (layer, level-key, weight
/// bits, sq_err bits) in iteration order.
fn db_bits(db: &ModelDb) -> Vec<(String, String, Vec<u32>, u64)> {
    db.entries()
        .map(|e| {
            (
                e.layer.clone(),
                e.level.key(),
                e.w.iter().map(|v| v.to_bits()).collect(),
                e.sq_err.to_bits(),
            )
        })
        .collect()
}

/// The no-store reference: a fresh identically-seeded engine building
/// live. Every degraded path must land on exactly these bits.
fn reference_db() -> Vec<(String, String, Vec<u32>, u64)> {
    let engine = CompressionEngine::synthetic(SYNTHETIC_SEED).unwrap();
    let (db, _) = jobs::db_for_spec(&engine, &spec()).unwrap();
    db_bits(&db)
}

#[test]
fn write_through_then_warm_start_bit_identical() {
    let dir = tmp_dir("warm");
    // Build live (write-through).
    let (e1, s1) = engine_with_store(&dir);
    let (db1, cached) = jobs::db_for_spec(&e1, &spec()).unwrap();
    assert!(!cached);
    assert_eq!(e1.db_builds(), 1, "live build counted");
    let st = s1.stats();
    assert_eq!((st.hits, st.misses, st.saves), (0, 1, 1), "{st:?}");

    // "Restart": fresh engine, fresh store handle, same directory.
    let (e2, s2) = engine_with_store(&dir);
    let (db2, _) = jobs::db_for_spec(&e2, &spec()).unwrap();
    assert_eq!(e2.db_builds(), 0, "warm start is NOT a build");
    let st2 = s2.stats();
    assert_eq!((st2.hits, st2.misses, st2.stale_rejected), (1, 0, 0), "{st2:?}");
    assert!(st2.load_seconds >= 0.0);

    // Snapshot == live build == no-store reference, bit for bit.
    assert_eq!(db_bits(&db1), db_bits(&db2), "warm-started db diverged");
    assert_eq!(db_bits(&db2), reference_db(), "snapshot path diverged from no-store path");

    // And the solver over the warm-started db answers identically too.
    let solve = |e: &CompressionEngine| {
        let r = jobs::execute(
            e,
            &JobSpec::Solve { db: spec(), target: TargetKind::Flop, value: 1.5 },
        )
        .unwrap();
        match r {
            JobResult::Solved { metric, achieved, .. } => (metric.to_bits(), achieved.to_bits()),
            other => panic!("expected Solved, got {other:?}"),
        }
    };
    let fresh = CompressionEngine::synthetic(SYNTHETIC_SEED).unwrap();
    assert_eq!(solve(&e2), solve(&fresh), "solve over snapshot differs from live");
}

/// Every corruption mode falls back to a live build that is
/// bit-identical to the no-store path, with the file quarantined and
/// the stale-rejected counter bumped.
#[test]
fn corrupt_snapshots_degrade_to_bit_identical_live_builds() {
    // Build one pristine snapshot to mutate.
    let pristine_dir = tmp_dir("corrupt_pristine");
    let (e0, s0) = engine_with_store(&pristine_dir);
    jobs::db_for_spec(&e0, &spec()).unwrap();
    let pristine_path = s0.snapshot_path(&e0.snapshot_key(&spec().cache_key()));
    let pristine = std::fs::read(&pristine_path).unwrap();
    let reference = reference_db();

    let cases: Vec<(&str, Vec<u8>)> = vec![
        ("truncated", pristine[..pristine.len() / 2].to_vec()),
        ("crc_flip", {
            let mut b = pristine.clone();
            let at = b.len() - 8; // inside the last entry's payload
            b[at] ^= 0x40;
            b
        }),
        ("bad_version", {
            let mut b = pristine.clone();
            b[4] = 99;
            b
        }),
        ("bad_magic", {
            let mut b = pristine.clone();
            b[0] = b'X';
            b
        }),
    ];
    for (name, bytes) in cases {
        let dir = tmp_dir(&format!("corrupt_{name}"));
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join(pristine_path.file_name().unwrap());
        std::fs::write(&file, &bytes).unwrap();

        let (engine, store) = engine_with_store(&dir);
        let (db, _) = jobs::db_for_spec(&engine, &spec()).unwrap();
        let st = store.stats();
        assert_eq!(st.stale_rejected, 1, "{name}: rejection counted ({st:?})");
        assert_eq!(st.hits, 0, "{name}: corrupt snapshot must not hit");
        assert_eq!(engine.db_builds(), 1, "{name}: live build ran");
        // The bad bytes were moved aside for post-mortem; the canonical
        // path now holds the fresh write-through from the live build.
        let quarantined = file.with_extension("obcdb.quarantined");
        assert!(quarantined.exists(), "{name}: rejected snapshot quarantined");
        assert_eq!(st.saves, 1, "{name}: live build wrote a fresh snapshot through");
        assert_eq!(db_bits(&db), reference, "{name}: degraded build diverged");
        // The live build wrote a fresh snapshot through; a re-run on the
        // same directory now warm-starts.
        let (e2, s2) = engine_with_store(&dir);
        jobs::db_for_spec(&e2, &spec()).unwrap();
        assert_eq!(s2.stats().hits, 1, "{name}: repaired store serves");
        assert_eq!(e2.db_builds(), 0, "{name}");
    }
}

/// A snapshot built under a different calibration (different synthetic
/// seed → different Hessians → different fingerprint) is stale: it must
/// be rejected, never served.
#[test]
fn stale_calibration_fingerprint_is_rejected() {
    let dir = tmp_dir("stale_fp");
    let seed9 = CompressionEngine::synthetic(9).unwrap();
    let store = Arc::new(SnapshotStore::open(&dir).unwrap());
    seed9.attach_store(Arc::clone(&store));
    jobs::db_for_spec(&seed9, &spec()).unwrap();

    // Same model name, same spec → same store key and file name; only
    // the fingerprint distinguishes the calibrations.
    let (e1, s1) = engine_with_store(&dir);
    assert_ne!(
        e1.calib_fingerprint(),
        seed9.calib_fingerprint(),
        "different seeds must fingerprint differently"
    );
    let (db, _) = jobs::db_for_spec(&e1, &spec()).unwrap();
    let st = s1.stats();
    assert_eq!(st.stale_rejected, 1, "stale snapshot rejected: {st:?}");
    assert_eq!(st.hits, 0);
    assert_eq!(e1.db_builds(), 1, "live build replaced the stale snapshot");
    assert_eq!(db_bits(&db), reference_db(), "fallback bit-identical to no-store");
}

/// Quarantine growth is bounded: past [`QUARANTINE_CAP`] rejected
/// snapshots for one key, the oldest quarantined file is evicted (and
/// counted) instead of accumulating forever. Churn alone never degrades
/// the store.
#[test]
fn quarantine_growth_is_capped_with_eviction() {
    let dir = tmp_dir("quarantine_cap");
    let (e0, s0) = engine_with_store(&dir);
    jobs::db_for_spec(&e0, &spec()).unwrap();
    let key = e0.snapshot_key(&spec().cache_key());
    let canonical = s0.snapshot_path(&key);
    let pristine = std::fs::read(&canonical).unwrap();

    let store = SnapshotStore::open(&dir).unwrap();
    let rounds = obc::store::QUARANTINE_CAP + 2;
    for i in 0..rounds {
        // Re-plant a (distinctly) corrupted snapshot at the canonical
        // path; each load must reject and move it aside.
        let mut bad = pristine.clone();
        bad[pristine.len() - 9 - (i % 4)] ^= 1;
        std::fs::write(&canonical, &bad).unwrap();
        assert!(
            store.load(&key, e0.calib_fingerprint()).is_none(),
            "round {i}: corrupt snapshot must not be served"
        );
    }

    let st = store.stats();
    assert_eq!(st.stale_rejected as usize, rounds, "{st:?}");
    assert_eq!(
        st.quarantine_evictions as usize,
        rounds - obc::store::QUARANTINE_CAP,
        "evictions past the cap: {st:?}"
    );
    assert!(!st.degraded, "quarantine churn is not degradation: {st:?}");
    let quarantined = std::fs::read_dir(&dir)
        .unwrap()
        .filter(|e| {
            e.as_ref().unwrap().file_name().to_string_lossy().contains("quarantined")
        })
        .count();
    assert_eq!(quarantined, obc::store::QUARANTINE_CAP, "at most CAP files kept per key");
}

#[test]
fn export_import_hands_snapshot_between_stores() {
    let export_dir = tmp_dir("export");
    std::fs::create_dir_all(&export_dir).unwrap();
    let exported = export_dir.join("handoff.obcdb");

    // Export from a store-less engine (what `obc db export` does).
    let engine = CompressionEngine::synthetic(SYNTHETIC_SEED).unwrap();
    let (db, _) = jobs::db_for_spec(&engine, &spec()).unwrap();
    let key = engine.snapshot_key(&spec().cache_key());
    snapfmt::write_snapshot_file(&exported, &key, engine.calib_fingerprint(), &db).unwrap();

    // Import into a fresh store (what `obc db import` does), then
    // warm-start a fresh engine from it.
    let import_dir = tmp_dir("import");
    let store = SnapshotStore::open(&import_dir).unwrap();
    let (got_key, entries) = store.import(&exported).unwrap();
    assert_eq!(got_key, key);
    assert_eq!(entries, db.len());

    let (e2, s2) = engine_with_store(&import_dir);
    let (db2, _) = jobs::db_for_spec(&e2, &spec()).unwrap();
    assert_eq!(e2.db_builds(), 0, "imported snapshot serves without a build");
    assert_eq!(s2.stats().hits, 1);
    assert_eq!(db_bits(&db2), db_bits(&db), "imported db bit-identical to exported");
}

/// The ISSUE acceptance: a server restarted against an existing
/// snapshot directory answers a db-backed job without rebuilding.
#[test]
fn restarted_server_answers_db_job_from_snapshot() {
    let dir = tmp_dir("server_restart");
    let cfg = || ServerConfig {
        workers: 2,
        queue_cap: 8,
        models_dir: PathBuf::from("/nonexistent"),
        synthetic_only: true,
        store_dir: Some(dir.clone()),
        ..ServerConfig::default()
    };
    let submit_db_job = |server: &CompressionServer| -> JobResult {
        let (tx, rx) = mpsc::channel();
        server
            .submit(SYNTHETIC_MODEL, JobSpec::BuildDb(spec()), Some("db".into()), tx)
            .unwrap();
        rx.recv().unwrap().outcome.unwrap()
    };
    let metric = |server: &CompressionServer, k: &str| -> f64 {
        server.metrics_json().get(k).unwrap().as_f64().unwrap()
    };

    // Cold process: builds and writes through.
    let server1 = CompressionServer::start(cfg());
    let r1 = submit_db_job(&server1);
    assert!(matches!(r1, JobResult::DbBuilt { cached: false, .. }), "{r1:?}");
    assert_eq!(metric(&server1, "db_builds"), 1.0);
    assert_eq!(metric(&server1, "store_saves"), 1.0);
    assert_eq!(metric(&server1, "store_hits"), 0.0);
    server1.shutdown();

    // Restarted process: same directory, fresh registry and caches.
    let server2 = CompressionServer::start(cfg());
    let r2 = submit_db_job(&server2);
    let (e1, e2) = match (&r1, &r2) {
        (JobResult::DbBuilt { entries: a, .. }, JobResult::DbBuilt { entries: b, .. }) => (*a, *b),
        other => panic!("expected DbBuilt pair, got {other:?}"),
    };
    assert_eq!(e1, e2, "same database either way");
    assert_eq!(metric(&server2, "store_hits"), 1.0, "answered from the snapshot");
    assert_eq!(metric(&server2, "db_builds"), 0.0, "no rebuild after restart");
    assert_eq!(metric(&server2, "store_stale_rejected"), 0.0);
    server2.shutdown();
}
