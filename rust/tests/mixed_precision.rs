//! Mixed-precision tier acceptance: the f32-storage / f64-accumulate
//! kernels pinned against the exact f64 oracles across the three
//! compression paths (unstructured pruning, N:M pruning, dense OBQ).
//!
//! The property being pinned is the *layer error*: narrowing H⁻¹ to f32
//! perturbs scores by O(f32 eps), which may flip near-tied selections,
//! but every selection the mixed sweep makes is near-optimal under the
//! same objective — so `sq_err` must track the f64 oracle to ~1e-4
//! relative on well-conditioned random layers.
//!
//! Lives in its own test binary because two tests install the
//! process-global precision policy; the lib unit tests (which assert
//! bitwise f64 behavior) must never share a process with that.

use obc::compress::exact_obs::{self, ObsOpts};
use obc::compress::hessian::LayerHessian;
use obc::compress::obq::{self, ObqOpts};
use obc::compress::sweep;
use obc::coordinator::methods::PruneMethod;
use obc::linalg::Mat;
use obc::util::pool::ThreadPool;
use obc::util::precision::{override_precision, set_global_precision, Precision};

/// Relative tolerance pinning the mixed tier's layer error to f64.
const TOL: f64 = 1e-4;

fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + b.abs())
}

/// A well-conditioned random layer: more samples than dimensions plus
/// the standard damping floor.
fn layer(rows: usize, d: usize, seed: u64) -> (Mat, LayerHessian) {
    let w = Mat::randn(rows, d, seed);
    let x = Mat::randn(d, 2 * d + 16, seed + 1000);
    (w, LayerHessian::from_inputs(&x, 1e-8))
}

#[test]
fn unstructured_mixed_error_tracks_f64() {
    for (seed, rows, d, sparsity) in
        [(11, 4, 48, 0.5), (12, 3, 64, 0.7), (13, 2, 96, 0.3)]
    {
        let (w, h) = layer(rows, d, seed);
        let exact = exact_obs::prune_unstructured(&w, &h, sparsity, &ObsOpts::default());
        for batch in [1usize, 8, 32] {
            let mixed = exact_obs::prune_unstructured(
                &w,
                &h,
                sparsity,
                &ObsOpts { batch, precision: Precision::Mixed, ..Default::default() },
            );
            // Same budget: Algorithm 2 prunes an exact global count.
            assert_eq!(
                mixed.sparsity, exact.sparsity,
                "seed {seed} B={batch}: sparsity"
            );
            assert!(
                close(mixed.sq_err, exact.sq_err, TOL),
                "seed {seed} B={batch}: mixed err {} vs f64 {}",
                mixed.sq_err,
                exact.sq_err
            );
        }
    }
}

#[test]
fn nm_mixed_keeps_the_pattern_and_tracks_f64() {
    let pool = ThreadPool::new(3);
    for (seed, rows, d, n_keep, m) in [(21, 4, 32, 2, 4), (22, 3, 64, 1, 4), (23, 2, 48, 4, 8)]
    {
        let (w, h) = layer(rows, d, seed);
        let exact =
            exact_obs::prune_nm_batched_on(&pool, &w, &h, n_keep, m, 1, Precision::F64);
        for batch in [1usize, 8] {
            let mixed = exact_obs::prune_nm_batched_on(
                &pool,
                &w,
                &h,
                n_keep,
                m,
                batch,
                Precision::Mixed,
            );
            // The structural contract is precision-independent: every
            // group of m keeps exactly n_keep weights.
            for r in 0..rows {
                for g in (0..d).step_by(m) {
                    let kept = mixed.w.row(r)[g..g + m]
                        .iter()
                        .filter(|&&v| v != 0.0)
                        .count();
                    assert_eq!(
                        kept, n_keep,
                        "seed {seed} B={batch} row {r} group {g}: {kept} kept"
                    );
                }
            }
            assert!(
                close(mixed.sq_err, exact.sq_err, TOL),
                "seed {seed} B={batch}: mixed err {} vs f64 {}",
                mixed.sq_err,
                exact.sq_err
            );
        }
    }
}

#[test]
fn dense_obq_mixed_error_tracks_f64() {
    for (seed, rows, d, bits) in [(31, 4, 48, 4), (32, 3, 64, 3), (33, 2, 96, 8)] {
        let (w, h) = layer(rows, d, seed);
        let f64_opts = ObqOpts { batch: 1, precision: Precision::F64, ..ObqOpts::new(bits) };
        let exact = obq::quantize(&w, &h, &f64_opts);
        for batch in [1usize, 8] {
            let opts = ObqOpts { batch, precision: Precision::Mixed, ..ObqOpts::new(bits) };
            let mixed = obq::quantize(&w, &h, &opts);
            // A near-tie can move a weight one grid step, but the grid
            // is shared and the error objective must track.
            assert!(
                close(mixed.sq_err, exact.sq_err, TOL),
                "seed {seed} B={batch} bits {bits}: mixed err {} vs f64 {}",
                mixed.sq_err,
                exact.sq_err
            );
        }
    }
}

/// The thread-scoped override is what the server installs per job: opts
/// constructors resolve through it, with no effect on other threads.
#[test]
fn thread_override_selects_the_mixed_tier() {
    let (w, h) = layer(3, 32, 41);
    let exact = obq::quantize(
        &w,
        &h,
        &ObqOpts { precision: Precision::F64, ..ObqOpts::new(4) },
    );
    let mixed = {
        let _tier = override_precision(Precision::Mixed);
        let opts = ObqOpts::new(4);
        assert_eq!(opts.precision, Precision::Mixed, "override resolves into opts");
        obq::quantize(&w, &h, &opts)
    };
    assert!(
        close(mixed.sq_err, exact.sq_err, TOL),
        "mixed err {} vs f64 {}",
        mixed.sq_err,
        exact.sq_err
    );
}

/// The process-global policy (what `OBC_PRECISION=mixed` sets at
/// startup) flows through method dispatch bit-identically to passing
/// explicit mixed opts. This is the only test in the binary that writes
/// the global, and every other test sets its precision explicitly, so
/// parallel test threads cannot observe a surprise policy.
#[test]
fn global_policy_flows_through_method_dispatch() {
    set_global_precision(Precision::Mixed);
    let (w, h) = layer(3, 32, 51);
    let got = PruneMethod::ExactObs.prune(&w, &h, 0.5);
    let want = exact_obs::prune_unstructured(
        &w,
        &h,
        0.5,
        &ObsOpts {
            batch: sweep::configured_batch(),
            precision: Precision::Mixed,
            ..Default::default()
        },
    );
    // Same kernels, same pool discipline → bitwise identical.
    assert_eq!(got.sq_err.to_bits(), want.sq_err.to_bits());
    assert_eq!(got.w.data, want.w.data);
}
