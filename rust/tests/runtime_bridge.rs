//! Integration: runtime dispatch correctness.
//!
//! Without the `pjrt` feature (the offline default), `runtime::dispatch`
//! must fall through to the native Rust kernels and reproduce the
//! library reference implementations bit-for-bit — no artifacts needed.
//!
//! With `--features pjrt`, the original three-way bridge check runs:
//!
//!   numpy oracle == Pallas kernel   (pytest, python/tests)
//!   Pallas-lowered HLO == native    (the `pjrt_bridge` module, via PJRT)
//!
//! Those tests skip gracefully when `make artifacts` has not run.

use obc::compress::exact_obs;
use obc::compress::hessian::LayerHessian;
use obc::compress::obq::{self, ObqOpts};
use obc::compress::quant::{fit_grids_per_row, GridSearch};
use obc::linalg::Mat;
use obc::runtime::dispatch;

#[test]
fn dispatch_obs_sweep_native_matches_reference() {
    let (d, rows) = (16, 4);
    let h = LayerHessian::synthetic(d, 1);
    let w = Mat::randn(rows, d, 2);
    let out = dispatch::obs_sweep(&w, &h.hinv).expect("dispatch obs_sweep");
    assert_eq!(out.traces.len(), rows);
    for r in 0..rows {
        let mut wr = w.row(r).to_vec();
        let mut hinv = h.hinv.clone();
        let t = exact_obs::sweep_row(&mut wr, &mut hinv, d, |_, _| true);
        assert_eq!(t.order, out.traces[r].order, "row {r} order");
        assert_eq!(t.dloss, out.traces[r].dloss, "row {r} dloss");
        assert_eq!(wr, out.w.row(r).to_vec(), "row {r} weights");
        assert!(out.w.row(r).iter().all(|&v| v == 0.0), "full sweep zeroes row {r}");
    }
}

#[test]
fn dispatch_obq_sweep_native_matches_reference() {
    let (d, rows) = (16, 3);
    let h = LayerHessian::synthetic(d, 3);
    let w = Mat::randn(rows, d, 4);
    let grids = fit_grids_per_row(&w, 4, false, GridSearch::MinMax);
    let got = dispatch::obq_sweep(&w, &h.hinv, &grids).expect("dispatch obq_sweep");
    let opts = ObqOpts::new(4);
    for r in 0..rows {
        let native = obq::quantize_row(w.row(r), &h.hinv, &grids[r], &opts);
        assert_eq!(native, got.row(r).to_vec(), "row {r}");
        for c in 0..d {
            let v = got.at(r, c);
            assert!((v - grids[r].quant(v)).abs() < 1e-9, "({r},{c}) off grid");
        }
    }
}

#[test]
fn dispatch_hessian_native_matches_accumulator() {
    let (d, n) = (12, 48);
    let x = Mat::randn(d, n, 5);
    let got = dispatch::hessian(&x).expect("dispatch hessian");
    let mut acc = obc::compress::hessian::HessianAccumulator::new(d);
    acc.add_batch(&x);
    assert_eq!(got.data, acc.raw().data, "2XXᵀ must be bit-identical");
}

#[cfg(feature = "pjrt")]
mod pjrt_bridge {
    use super::*;
    use obc::runtime::dispatch::pjrt;
    use obc::runtime::Runtime;

    fn runtime_or_skip() -> Option<Runtime> {
        match Runtime::new() {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("SKIP runtime tests: {e}");
                None
            }
        }
    }

    #[test]
    fn obs_sweep_pjrt_matches_native() {
        let Some(rt) = runtime_or_skip() else { return };
        let d = 32;
        let rows = 8;
        let h = LayerHessian::synthetic(d, 1);
        let w = Mat::randn(rows, d, 2);
        let Some(res) = pjrt::obs_sweep_pjrt(&rt, &w, &h.hinv) else {
            eprintln!("SKIP: no obs artifact for d={d}");
            return;
        };
        let out = res.expect("pjrt obs sweep");
        assert_eq!(out.traces.len(), rows);
        for r in 0..rows {
            // Native reference.
            let mut wr = w.row(r).to_vec();
            let mut hinv = h.hinv.clone();
            let trace = exact_obs::sweep_row(&mut wr, &mut hinv, d, |_, _| true);
            // Same selection order (f32 kernel vs f64 native can only diverge
            // on near-ties; require ≥90% prefix agreement and final zeros).
            let agree = trace
                .order
                .iter()
                .zip(&out.traces[r].order)
                .take_while(|(a, b)| a == b)
                .count();
            assert!(
                agree * 10 >= d * 9,
                "row {r}: order agreement only {agree}/{d}"
            );
            assert!(out.w.row(r).iter().all(|&v| v == 0.0), "full sweep must zero row");
            // Loss traces close where orders agree.
            for i in 0..agree {
                let a = trace.dloss[i];
                let b = out.traces[r].dloss[i];
                assert!(
                    (a - b).abs() <= 1e-3 + 0.02 * a.abs().max(b.abs()),
                    "row {r} step {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn obq_sweep_pjrt_matches_native() {
        let Some(rt) = runtime_or_skip() else { return };
        let d = 32;
        let rows = 8;
        let h = LayerHessian::synthetic(d, 3);
        let w = Mat::randn(rows, d, 4);
        let grids = fit_grids_per_row(&w, 4, false, GridSearch::MinMax);
        let pairs: Vec<(f64, f64)> = grids.iter().map(|g| (g.scale, g.zero)).collect();
        let Some(res) = pjrt::obq_sweep_pjrt(&rt, &w, &h.hinv, &pairs) else {
            eprintln!("SKIP: no obq artifact for d={d}");
            return;
        };
        let got = res.expect("pjrt obq sweep");
        // Native (outlier heuristic on, same as the artifact).
        let opts = ObqOpts::new(4);
        for r in 0..rows {
            let native = obq::quantize_row(w.row(r), &h.hinv, &grids[r], &opts);
            // Quantized outputs live on a coarse grid: require most entries
            // to match exactly and all to be on-grid.
            let mut same = 0;
            for c in 0..d {
                let gv = got.at(r, c);
                let snapped = grids[r].quant(gv);
                assert!((gv - snapped).abs() < 1e-5, "({r},{c}) off grid");
                if (gv - native[c]).abs() < 1e-6 {
                    same += 1;
                }
            }
            assert!(same * 10 >= d * 8, "row {r}: only {same}/{d} grid points agree");
        }
    }

    #[test]
    fn hessian_pjrt_matches_native() {
        let Some(rt) = runtime_or_skip() else { return };
        let (d, n) = (32, 128);
        let x = Mat::randn(d, n, 5);
        let Some(res) = pjrt::hessian_pjrt(&rt, &x) else {
            eprintln!("SKIP: no hessian artifact for d={d} n={n}");
            return;
        };
        let got = res.expect("pjrt hessian");
        let want = {
            let mut acc = obc::compress::hessian::HessianAccumulator::new(d);
            acc.add_batch(&x);
            acc.raw()
        };
        let scale = want.diag_mean().max(1.0);
        assert!(got.dist(&want) < 1e-3 * scale, "dist {}", got.dist(&want));
    }

    #[test]
    fn model_forward_hlo_matches_native_engine() {
        // The L2 bridge check: the JAX-lowered forward pass of the trained
        // rneta, executed via PJRT, must match our native inference engine on
        // the same inputs (proving the Rust engine implements the same
        // network the build-time trainer produced).
        let Some(rt) = runtime_or_skip() else { return };
        let Some(art) = rt.manifest.find("rneta_fwd_b4") else {
            eprintln!("SKIP: no rneta_fwd artifact");
            return;
        };
        let dir = obc::util::io::artifacts_dir().join("models");
        let Ok(bundle) = obc::nn::models::load_bundle(&dir, "rneta") else {
            eprintln!("SKIP: rneta not trained");
            return;
        };
        let x = obc::nn::models::batch_slice(&bundle.test_x, 0, 4);
        let native = bundle.model.forward(&x);
        // The artifact takes (x, params..., state...) sorted by name — the
        // text printer elides big constants, so weights are arguments.
        let raw = obc::util::io::load_obcw(&dir.join("rneta.obcw")).expect("load bundle");
        let mut inputs: Vec<(&[f32], Vec<i64>)> = vec![(&x.data, vec![4, 3, 16, 16])];
        for prefix in ["param.", "state."] {
            for (k, t) in &raw {
                if k.starts_with(prefix) {
                    inputs.push((&t.data, t.shape.iter().map(|&d| d as i64).collect()));
                }
            }
        }
        let input_refs: Vec<(&[f32], &[i64])> =
            inputs.iter().map(|(d, s)| (*d, s.as_slice())).collect();
        let outs = rt.run_f32(&art.name, &input_refs).expect("run fwd artifact");
        let jax_logits = &outs[0];
        assert_eq!(jax_logits.len(), native.data.len());
        for (i, (a, b)) in jax_logits.iter().zip(&native.data).enumerate() {
            assert!(
                (a - b).abs() < 1e-2 + 1e-2 * b.abs(),
                "logit {i}: jax {a} vs native {b}"
            );
        }
        // And identical argmax (the metric-relevant property).
        let native_pred = native.argmax_last();
        for i in 0..4 {
            let row = &jax_logits[i * 16..(i + 1) * 16];
            let jp = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            assert_eq!(jp, native_pred[i], "sample {i} argmax differs");
        }
    }
}
